//! Overload-robustness suite (DESIGN.md §13): admission control,
//! deadlines, load shedding, and per-client quotas for the online
//! service.
//!
//! The load-bearing property ("shedding exactness"): under a bounding
//! [`AdmissionPolicy`] every submitted query request gets **exactly
//! one** outcome - a full answer, or one typed [`Rejected`] - and the
//! answers a shedding service produces are *bit-identical* to the
//! deterministic replay of the same queries through an unloaded
//! engine, across all three `DrainMode`s with fault injection layered
//! on top. Shedding changes *which* requests are answered, never *what
//! any answer contains*: shed points sit outside every flush, so the
//! exactly-once claim accounting and replay-mode purity of the serve
//! loop are untouched.
//!
//! Also here: the typed synchronous rejections (global bound,
//! per-client bound, token-bucket quota), deadline sheds with the
//! explicit-deadline override, overload-triggered degradation
//! tightening the effective bound from live CPU-only throughput, and
//! the ISSUE 10 small fix - a client handed out after the serve loop
//! terminated fails fast with [`Rejected::Terminated`] instead of
//! parking forever on a condvar nobody will ever signal.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::rng::Rng;

/// CI's chaos matrix pins the drain depth via `HKNN_FAULT_DEPTH`
/// (1 = sync, 2 = two-stage, 3 = three-stage); unset, the harness
/// sweeps all three itself.
fn drain_modes() -> Vec<DrainMode> {
    match std::env::var("HKNN_FAULT_DEPTH").ok().as_deref() {
        Some("1") => vec![DrainMode::Sync],
        Some("2") => vec![DrainMode::TwoStage],
        Some("3") => vec![DrainMode::ThreeStage],
        _ => vec![DrainMode::Sync, DrainMode::TwoStage, DrainMode::ThreeStage],
    }
}

fn small_session<'e>(
    engine: &'e Engine,
    corpus: &Dataset,
) -> KnnEngine<'e> {
    let mut p = HybridParams::new(3);
    p.cpu_ranks = 0; // deterministic replay mode
    KnnEngine::build(engine, corpus, p).unwrap()
}

#[test]
fn full_pending_bound_rejects_synchronously_with_typed_overloaded() {
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(400).generate(0xA1);
    let queries = susy_like(8).generate(0xA2);
    let mut session = small_session(&engine, &corpus);
    let policy = AdmissionPolicy {
        max_pending_queries: 2,
        ..AdmissionPolicy::default()
    };
    let ingress = Ingress::with_policy(policy);
    std::thread::scope(|s| {
        // fill the queue to its bound before the serve loop starts
        let c1 = ingress.client();
        let q01 = queries.gather(&[0, 1]);
        let blocked = s.spawn(move || c1.query(&q01).unwrap());
        while ingress.pending_queries() < 2 {
            std::thread::sleep(Duration::from_millis(1));
        }
        // one more row overflows the bound: rejected synchronously,
        // under the ingress lock, without ever occupying a queue slot
        let probe = ingress.client();
        let err = probe.query(&queries.gather(&[2])).unwrap_err();
        match err.downcast_ref::<Rejected>() {
            Some(Rejected::Overloaded { retry_after_hint }) => {
                assert!(*retry_after_hint >= Duration::from_millis(1));
            }
            other => panic!("wrong rejection: {other:?}"),
        }
        assert!(err.to_string().contains("pending queue full"));
        drop(probe);
        // mutations are exempt: corpus state transitions are admitted
        // even at a full query bound
        let c3 = ingress.client();
        let ins_batch = queries.gather(&[3]);
        let inserter = s.spawn(move || c3.insert(&ins_batch).unwrap());
        while ingress.pending_len() < 2 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let rep = session.serve(&ingress).unwrap();
        let reply = blocked.join().expect("blocked client panicked");
        assert_eq!(reply.results.len(), 2, "admitted request fully served");
        let ids = inserter.join().expect("insert client panicked");
        assert_eq!(ids.len(), 1, "mutation admitted at a full bound");
        assert_eq!(rep.admitted, 2);
        assert_eq!(rep.queries, 2);
        assert_eq!(rep.shed_overload, 1);
        assert_eq!(rep.rejected_requests, 1);
        assert_eq!(rep.inserts, 1);
    });
}

#[test]
fn per_client_bound_isolates_the_greedy_client() {
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(400).generate(0xB1);
    let queries = susy_like(8).generate(0xB2);
    let mut session = small_session(&engine, &corpus);
    let policy = AdmissionPolicy {
        max_pending_per_client: 2,
        ..AdmissionPolicy::default()
    };
    let ingress = Ingress::with_policy(policy);
    let ready = AtomicBool::new(false);
    std::thread::scope(|s| {
        let ca = ingress.client();
        let cb = ingress.client();
        let (ingress_r, queries_r, ready_r) = (&ingress, &queries, &ready);
        let driver = s.spawn(move || {
            std::thread::scope(|s2| {
                let qa = queries_r.gather(&[0, 1]);
                let ha = s2.spawn(|| ca.query(&qa).unwrap());
                while ingress_r.pending_queries() < 2 {
                    std::thread::sleep(Duration::from_millis(1));
                }
                // the same client is over its per-client bound...
                let err = ca.query(&queries_r.gather(&[2])).unwrap_err();
                match err.downcast_ref::<Rejected>() {
                    Some(Rejected::Overloaded { .. }) => {}
                    other => panic!("wrong rejection: {other:?}"),
                }
                // ...but the global queue still has room for everyone
                // else: a second client is admitted untouched
                let qb = queries_r.gather(&[3]);
                let hb = s2.spawn(|| cb.query(&qb).unwrap());
                while ingress_r.pending_queries() < 3 {
                    std::thread::sleep(Duration::from_millis(1));
                }
                ready_r.store(true, Ordering::Release);
                (ha.join().unwrap(), hb.join().unwrap())
            })
        });
        while !ready.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_millis(1));
        }
        let rep = session.serve(&ingress).unwrap();
        let (ra, rb) = driver.join().expect("driver panicked");
        assert_eq!(ra.results.len(), 2);
        assert_eq!(rb.results.len(), 1);
        assert_eq!(rep.admitted, 3);
        assert_eq!(rep.queries, 3);
        assert_eq!(rep.shed_overload, 1);
        assert_eq!(rep.rejected_requests, 1);
    });
}

#[test]
fn token_bucket_quota_rejects_the_aggressive_client_only() {
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(400).generate(0xC1);
    let queries = susy_like(8).generate(0xC2);
    let mut session = small_session(&engine, &corpus);
    let policy = AdmissionPolicy {
        quota: Some(ClientQuota { rate_qps: 0.0, burst: 2.0 }),
        ..AdmissionPolicy::default()
    };
    let ingress = Ingress::with_policy(policy);
    std::thread::scope(|s| {
        let greedy = ingress.client();
        let modest = ingress.client();
        let queries_r = &queries;
        let driver = s.spawn(move || {
            // the burst admits two rows (served one at a time while the
            // loop runs - the bucket is charged at admission, so the
            // draining below does not refill anything at rate 0)
            let r1 = greedy.query(&queries_r.gather(&[0])).unwrap();
            let r2 = greedy.query(&queries_r.gather(&[1])).unwrap();
            let err = greedy.query(&queries_r.gather(&[2])).unwrap_err();
            let wait = match err.downcast_ref::<Rejected>() {
                Some(Rejected::QuotaExceeded { retry_after }) => *retry_after,
                other => panic!("wrong rejection: {other:?}"),
            };
            assert!(wait >= Duration::from_secs(3600), "zero rate: {wait:?}");
            assert!(err.to_string().contains("client quota exhausted"));
            // mutations are never rate-limited
            let ids = greedy.insert(&queries_r.gather(&[4])).unwrap();
            assert_eq!(ids.len(), 1);
            // an independent client draws from its own bucket
            let r3 = modest.query(&queries_r.gather(&[3])).unwrap();
            (r1, r2, r3)
        });
        let rep = session.serve(&ingress).unwrap();
        let (r1, r2, r3) = driver.join().expect("driver panicked");
        assert_eq!(
            r1.results.len() + r2.results.len() + r3.results.len(),
            3
        );
        assert_eq!(rep.admitted, 3);
        assert_eq!(rep.queries, 3);
        assert_eq!(rep.shed_quota, 1);
        assert_eq!(rep.rejected_requests, 1);
        assert_eq!(rep.shed_overload + rep.shed_deadline, 0);
        assert_eq!(rep.inserts, 1);
    });
}

#[test]
fn expired_deadline_sheds_before_pricing_with_typed_error() {
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(400).generate(0xD1);
    let queries = susy_like(8).generate(0xD2);
    let mut session = small_session(&engine, &corpus);
    // a generous default deadline; the doomed request overrides it with
    // its own 2 ms one (the explicit deadline wins over the policy's)
    let policy = AdmissionPolicy {
        default_deadline: Some(Duration::from_secs(10)),
        ..AdmissionPolicy::default()
    };
    let ingress = Ingress::with_policy(policy);
    std::thread::scope(|s| {
        let c1 = ingress.client();
        let q_dead = queries.gather(&[0, 1]);
        let doomed = s.spawn(move || {
            c1.query_with_deadline(&q_dead, Duration::from_millis(2))
                .unwrap_err()
        });
        while ingress.pending_queries() < 2 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let c2 = ingress.client();
        let q_live = queries.gather(&[2]);
        let served = s.spawn(move || c2.query(&q_live).unwrap());
        while ingress.pending_queries() < 3 {
            std::thread::sleep(Duration::from_millis(1));
        }
        // let the 2 ms deadline lapse before the serve loop ever runs:
        // its first cycle must shed the stale request *before* pricing
        std::thread::sleep(Duration::from_millis(20));
        let rep = session.serve(&ingress).unwrap();
        let err = doomed.join().expect("doomed client panicked");
        match err.downcast_ref::<Rejected>() {
            Some(Rejected::DeadlineExpired { missed_by }) => {
                assert!(*missed_by > Duration::ZERO);
            }
            other => panic!("wrong rejection: {other:?}"),
        }
        assert!(err.to_string().contains("deadline expired"));
        let reply = served.join().expect("served client panicked");
        assert_eq!(reply.results.len(), 1, "in-deadline request answered");
        assert_eq!(rep.admitted, 3);
        assert_eq!(rep.queries, 1, "only the live row was priced");
        assert_eq!(rep.shed_deadline, 2);
        assert_eq!(rep.rejected_requests, 1);
        assert_eq!(rep.requests, 1);
    });
}

/// One deterministic overload schedule against one drain mode:
///
/// 1. five doomed requests (2 ms deadlines) fill the queue to its
///    bound before the serve loop starts;
/// 2. with the queue exactly full, one probe row overflows it and is
///    rejected synchronously;
/// 3. the deadlines lapse, the serve loop's first cycle sheds the
///    whole doomed backlog, and three closed-loop clients (gated on
///    that shed, sized so their in-flight rows can never re-fill the
///    bound) stream the remaining 36 queries through the loaded
///    service.
///
/// Asserts the full shedding-exactness contract: disjoint exactly-once
/// outcomes client-side, matching admission ledger service-side
/// (admitted == served + shed), and answered results bit-identical to
/// the unloaded deterministic replay - with a transient GPU fault
/// injected under everything.
fn overload_schedule(
    engine: &Engine,
    mode: DrainMode,
    shed: ShedPolicy,
    seed: u64,
) {
    const BOUND: usize = 10;
    let corpus = susy_like(400).generate(seed);
    let queries = susy_like(47).generate(seed ^ 0x7E57);
    let mut p = HybridParams::new(4);
    p.cpu_ranks = 0; // deterministic replay mode
    p.gpu_drain = mode;
    p.streams = 2;
    p.fault =
        FaultPlan::one(FaultSpec::transient(FaultKind::FilterPanic, 0, 0));
    p.recovery.backoff_base_secs = 0.0;
    let tag = format!("{mode:?}/{shed:?}");

    // the unloaded reference: one deterministic batch replay over the
    // closed-loop clients' whole query union
    let loop_ids: Vec<usize> = (0..36).collect();
    let mut ref_session =
        KnnEngine::build(engine, &corpus, p.clone()).unwrap();
    let (ref_result, _) =
        ref_session.flush(&queries.gather(&loop_ids)).unwrap();

    let mut session = KnnEngine::build(engine, &corpus, p).unwrap();
    let policy = AdmissionPolicy {
        max_pending_queries: BOUND,
        shed_policy: shed,
        ..AdmissionPolicy::default()
    };
    let ingress = Ingress::with_policy(policy);

    // per-client chunk plans over disjoint strided slices of 0..36
    let mut rng = Rng::new(seed ^ 0xC4A0);
    let mut plans: Vec<Vec<Vec<usize>>> = Vec::new();
    for c in 0..3 {
        let ids: Vec<usize> = (c..36).step_by(3).collect();
        let mut chunks = Vec::new();
        let mut i = 0usize;
        while i < ids.len() {
            let take = (1 + rng.below(3)).min(ids.len() - i);
            chunks.push(ids[i..i + take].to_vec());
            i += take;
        }
        plans.push(chunks);
    }

    std::thread::scope(|s| {
        // phase 1: fill the queue to the bound with doomed requests
        let prefill: Vec<_> = (0..5)
            .map(|i| {
                let client = ingress.client();
                let batch = queries.gather(&[36 + 2 * i, 37 + 2 * i]);
                s.spawn(move || {
                    client
                        .query_with_deadline(&batch, Duration::from_millis(2))
                        .unwrap_err()
                })
            })
            .collect();
        while ingress.pending_queries() < BOUND {
            std::thread::sleep(Duration::from_millis(1));
        }
        // phase 2: the queue is exactly full - one more row overflows
        {
            let probe = ingress.client();
            let err = probe.query(&queries.gather(&[46])).unwrap_err();
            match err.downcast_ref::<Rejected>() {
                Some(Rejected::Overloaded { retry_after_hint }) => {
                    assert!(*retry_after_hint >= Duration::from_millis(1));
                }
                other => panic!("{tag}: wrong rejection {other:?}"),
            }
        }
        std::thread::sleep(Duration::from_millis(20)); // deadlines lapse
        // phase 3: closed-loop clients, gated until the doomed backlog
        // has been shed. 3 clients x <=3 rows in flight <= BOUND, so
        // no loop submission can ever see a full queue: the schedule
        // is deterministic end to end.
        let loopers: Vec<_> = plans
            .iter()
            .map(|chunks| {
                let client = ingress.client();
                let ingress_r = &ingress;
                let queries_r = &queries;
                s.spawn(move || {
                    while ingress_r.admission_stats().shed_deadline < BOUND {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    let mut out = Vec::new();
                    for chunk in chunks {
                        let reply =
                            client.query(&queries_r.gather(chunk)).unwrap();
                        out.push((chunk.clone(), reply));
                    }
                    out
                })
            })
            .collect();
        let rep = session.serve(&ingress).unwrap();
        // every doomed request got exactly one typed DeadlineExpired
        for h in prefill {
            let err = h.join().expect("prefill client panicked");
            match err.downcast_ref::<Rejected>() {
                Some(Rejected::DeadlineExpired { missed_by }) => {
                    assert!(*missed_by > Duration::ZERO, "{tag}");
                }
                other => panic!("{tag}: wrong shed {other:?}"),
            }
        }
        // answered side: exactly-once coverage, bit-identical payloads
        let mut seen = vec![false; 36];
        let mut answered_rows = 0usize;
        let mut answered_requests = 0usize;
        for h in loopers {
            for (ids, reply) in h.join().expect("loop client panicked") {
                answered_requests += 1;
                assert_eq!(ids.len(), reply.results.len(), "{tag}: shape");
                for (j, &g) in ids.iter().enumerate() {
                    assert!(!seen[g], "{tag}: q={g} answered twice");
                    seen[g] = true;
                    answered_rows += 1;
                    let want = ref_result.get(g);
                    let got = &reply.results[j];
                    assert_eq!(
                        got.ids.as_slice(),
                        want.ids(),
                        "{tag} q={g}: id lane diverged under load"
                    );
                    assert_eq!(
                        got.dist2.as_slice(),
                        want.dist2s(),
                        "{tag} q={g}: dist2 lane diverged under load"
                    );
                }
            }
        }
        assert!(
            seen.iter().all(|&b| b),
            "{tag}: shedding starved a live query"
        );
        assert_eq!(answered_rows, 36, "{tag}");
        // the admission ledger: every admitted row is served or shed,
        // never both, never neither
        assert_eq!(rep.queries, answered_rows, "{tag}: served rows");
        assert_eq!(
            rep.admitted,
            BOUND + answered_rows,
            "{tag}: admitted == served + shed"
        );
        assert_eq!(rep.shed_deadline, BOUND, "{tag}: the doomed backlog");
        assert_eq!(rep.shed_overload, 1, "{tag}: the overflow probe");
        assert_eq!(rep.shed_quota, 0, "{tag}");
        assert_eq!(
            rep.rejected_requests,
            5 + 1,
            "{tag}: one typed rejection per non-answered request"
        );
        assert_eq!(rep.requests, answered_requests, "{tag}");
        assert_eq!(rep.q_gpu, answered_rows, "{tag}: GPU-first replay");
        assert!(
            rep.gpu_faults >= 1,
            "{tag}: the injected fault was observed"
        );
    });
}

#[test]
fn shedding_is_exact_across_drain_modes_under_fault_injection() {
    let engine = Engine::load_default().unwrap();
    for (i, mode) in drain_modes().into_iter().enumerate() {
        // alternate the victim-selection policy across the sweep so
        // both ShedPolicy arms run under real load
        let shed = if i % 2 == 0 {
            ShedPolicy::NewestFirst
        } else {
            ShedPolicy::ByDeadline
        };
        overload_schedule(&engine, mode, shed, 0x0AD5 ^ ((i as u64) << 8));
    }
}

#[test]
fn degraded_engine_tightens_the_admission_bound_and_stays_exact() {
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(500).generate(0xDE5);
    let queries = susy_like(16).generate(0xDE6);
    let mut p = HybridParams::new(3);
    p.cpu_ranks = 0;
    // a persistent GPU fault plus an immediate demotion threshold:
    // every flush finishes CPU-only and reports degraded = true
    p.fault = FaultPlan::one(FaultSpec::persistent(FaultKind::FilterPanic, 0));
    p.recovery.demote_after = 1;
    p.recovery.backoff_base_secs = 0.0;
    // the reference engine degrades identically (same FaultPlan): the
    // CPU-only answers are still a pure function of (corpus, eps, k)
    let mut ref_session =
        KnnEngine::build(&engine, &corpus, p.clone()).unwrap();
    let all: Vec<usize> = (0..16).collect();
    let (ref_result, ref_rep) =
        ref_session.flush(&queries.gather(&all)).unwrap();
    assert!(ref_rep.degraded, "persistent fault must demote the master");

    let mut session = KnnEngine::build(&engine, &corpus, p).unwrap();
    const CONFIGURED: usize = 1_000_000;
    let policy = AdmissionPolicy {
        max_pending_queries: CONFIGURED,
        ..AdmissionPolicy::default()
    };
    let ingress = Ingress::with_policy(policy);
    assert_eq!(ingress.effective_max_pending(), CONFIGURED);
    std::thread::scope(|s| {
        let client = ingress.client();
        let (ingress_r, queries_r) = (&ingress, &queries);
        let driver = s.spawn(move || {
            let ids1: Vec<usize> = (0..8).collect();
            let r1 = client.query(&queries_r.gather(&ids1)).unwrap();
            // the serve loop feeds the capacity controller before it
            // replies, so by the time r1 is in hand the degraded
            // flush has already tightened the effective bound
            let tightened = ingress_r.effective_max_pending();
            let ids2: Vec<usize> = (8..16).collect();
            let r2 = client.query(&queries_r.gather(&ids2)).unwrap();
            (r1, tightened, r2)
        });
        let rep = session.serve(&ingress).unwrap();
        let (r1, tightened, r2) = driver.join().expect("driver panicked");
        assert!(
            tightened < CONFIGURED,
            "degradation must tighten the bound: {tightened}"
        );
        assert!(tightened >= 1, "the bound never tightens to zero");
        assert_eq!(
            rep.degraded_flushes, rep.flushes,
            "every flush ran CPU-only"
        );
        assert!(rep.flushes >= 2);
        // graceful degradation serves everything, exactly
        for (base, reply) in [(0usize, &r1), (8usize, &r2)] {
            assert_eq!(reply.results.len(), 8);
            for (j, got) in reply.results.iter().enumerate() {
                let want = ref_result.get(base + j);
                assert_eq!(got.ids.as_slice(), want.ids(), "q={}", base + j);
                assert_eq!(
                    got.dist2.as_slice(),
                    want.dist2s(),
                    "q={}",
                    base + j
                );
            }
        }
        assert_eq!(rep.queries, 16);
        assert_eq!(rep.admitted, 16);
        assert_eq!(rep.rejected_requests, 0);
    });
}

#[test]
fn late_client_after_termination_gets_typed_errors_not_deadlock() {
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(300).generate(0x7E1);
    let mut session = small_session(&engine, &corpus);
    let queries = susy_like(3).generate(0x7E2);
    let ingress = Ingress::new();
    // no clients registered: the serve loop exits immediately...
    let rep = session.serve(&ingress).unwrap();
    assert_eq!(rep.queries, 0);
    assert_eq!(rep.requests, 0);
    // ...and a client handed out afterwards must fail fast on every
    // call - query, insert, remove - never park on a condvar the dead
    // loop will never signal again (the ISSUE 10 small fix)
    let late = ingress.client();
    for err in [
        late.query(&queries).unwrap_err(),
        late.insert(&queries.gather(&[0])).unwrap_err(),
        late.remove(&[0]).unwrap_err(),
    ] {
        match err.downcast_ref::<Rejected>() {
            Some(Rejected::Terminated) => {}
            other => panic!("wrong rejection: {other:?}"),
        }
        assert!(err.to_string().contains("service has terminated"));
    }
    drop(late);
    // a fresh serve on the same ingress re-arms it
    let queries_r = &queries;
    std::thread::scope(|s| {
        let client = ingress.client();
        let h = s.spawn(move || {
            // a submission racing the restart may still see Terminated;
            // bounded retries must land once the loop is live again
            for _ in 0..2000 {
                match client.query(queries_r) {
                    Ok(r) => return r,
                    Err(e) => {
                        assert!(matches!(
                            e.downcast_ref::<Rejected>(),
                            Some(Rejected::Terminated)
                        ));
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
            panic!("restarted serve never answered");
        });
        let rep2 = session.serve(&ingress).unwrap();
        let reply = h.join().expect("late client panicked");
        assert_eq!(reply.results.len(), queries.len());
        assert_eq!(rep2.queries, queries.len());
    });
}
