//! Scheduler-level integration tests: the density-ordered work queue
//! against the paper's one-shot static split, without touching the
//! device layer (everything here is host-side and deterministic).
//!
//! The load-imbalance tests run both schedulers in *virtual time*: each
//! actor (1 GPU master + |p| CPU ranks) owns a clock, claims work through
//! the real queue machinery, and advances its clock by est_work/speed.
//! This isolates scheduling quality from wall-clock noise - the same
//! trick `cpu::rank_work_times` uses for Fig. 6.

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::sched::{build_queue, first_batch_work, next_batch_work};
use hybrid_knn_join::util::prop;

/// Virtual-time outcome of one schedule.
#[derive(Debug)]
struct Sim {
    /// finish time of the whole join
    makespan: f64,
    /// (makespan - earlier architecture finish) / makespan: the fraction
    /// of the run one architecture spent idle after exhausting its share
    idle_frac: f64,
    gpu_queries: usize,
    cpu_queries: usize,
}

/// Drain `queue` in virtual time: the GPU master claims head batches
/// sized by the live policy, `ranks` CPU actors chunk through the tail.
fn simulate_dynamic(
    queue: &WorkQueue,
    gpu_speed: f64,
    cpu_speed: f64,
    ranks: usize,
    chunk: usize,
) -> Sim {
    let mut gpu_clock = 0.0f64;
    let mut gpu_open = true;
    let mut cpu_clocks = vec![0.0f64; ranks];
    let mut cpu_open = vec![true; ranks];
    let (mut gpu_queries, mut cpu_queries) = (0usize, 0usize);
    let mut target = first_batch_work(
        queue.head_work_remaining(queue.len()),
        queue.dense_work(),
    );
    loop {
        // the actor whose clock is furthest behind claims next (CPU wins
        // ties so the order is deterministic)
        let mut best: Option<(f64, usize)> = None;
        for (i, &c) in cpu_clocks.iter().enumerate() {
            if cpu_open[i] && best.map(|(bc, _)| c < bc).unwrap_or(true) {
                best = Some((c, i));
            }
        }
        if gpu_open && best.map(|(bc, _)| gpu_clock < bc).unwrap_or(true) {
            best = Some((gpu_clock, ranks));
        }
        let Some((_, actor)) = best else { break };
        if actor == ranks {
            match queue.claim_head_work(target, queue.len()) {
                Some(r) => {
                    let w = queue.range_work(r.clone());
                    gpu_clock += w as f64 / gpu_speed;
                    gpu_queries += r.len();
                    target = next_batch_work(
                        queue.head_work_remaining(queue.len()),
                        gpu_speed,
                        cpu_speed * ranks as f64,
                    );
                }
                None => gpu_open = false,
            }
        } else {
            match queue.claim_tail(chunk) {
                Some(r) => {
                    let w = queue.range_work(r.clone());
                    cpu_clocks[actor] += w as f64 / cpu_speed;
                    cpu_queries += r.len();
                }
                None => cpu_open[actor] = false,
            }
        }
    }
    let cpu_finish = cpu_clocks.iter().cloned().fold(0.0, f64::max);
    let makespan = cpu_finish.max(gpu_clock);
    let idle_frac = if makespan > 0.0 {
        (makespan - cpu_finish.min(gpu_clock)) / makespan
    } else {
        0.0
    };
    Sim { makespan, idle_frac, gpu_queries, cpu_queries }
}

/// Drain `queue` in virtual time with the GPU master's
/// exec/transfer/filter split modeled explicitly: executing a claim of
/// work w costs w/gpu_speed, its device-to-host transfer costs
/// w*transfer_frac/gpu_speed, and its host filtering costs
/// w*filter_frac/gpu_speed. `depth` picks the drain:
///
/// * 1 = synchronous: the master pays exec + transfer + filter serially
///   per claim;
/// * 2 = two-stage: the master pays exec + transfer (the copy stays on
///   the master thread), filtering runs on its own stage; exec of claim
///   j waits for filter completion of claim j-2 (two staging sets);
/// * 3 = three-stage: the master pays exec alone, transfer and filter
///   each run on their own serial stage; exec of claim j waits for
///   filter completion of claim j-3 (three staging sets).
///
/// The claim-ahead sizing reads the master-side rate each mode actually
/// observes: total rate (sync), exec+transfer rate (two-stage), or the
/// kernel-only rate (three-stage).
#[allow(clippy::too_many_arguments)]
fn simulate_overlap(
    queue: &WorkQueue,
    gpu_speed: f64,
    transfer_frac: f64,
    filter_frac: f64,
    cpu_speed: f64,
    ranks: usize,
    chunk: usize,
    depth: usize,
) -> Sim {
    assert!((1..=3).contains(&depth));
    // when the master can next claim+execute / when the transfer and
    // filter stages free up / filter completion of the staging sets
    let mut exec_free = 0.0f64;
    let mut transfer_free = 0.0f64;
    let mut filter_free = 0.0f64;
    let mut stage_filter_end = [0.0f64; 3];
    let mut claim_idx = 0usize;
    let mut gpu_open = true;
    let mut cpu_clocks = vec![0.0f64; ranks];
    let mut cpu_open = vec![true; ranks];
    let (mut gpu_queries, mut cpu_queries) = (0usize, 0usize);
    let mut target = first_batch_work(
        queue.head_work_remaining(queue.len()),
        queue.dense_work(),
    );
    loop {
        let gpu_clock = if depth == 1 {
            filter_free.max(transfer_free).max(exec_free)
        } else {
            exec_free.max(stage_filter_end[claim_idx % depth])
        };
        let mut best: Option<(f64, usize)> = None;
        for (i, &c) in cpu_clocks.iter().enumerate() {
            if cpu_open[i] && best.map(|(bc, _)| c < bc).unwrap_or(true) {
                best = Some((c, i));
            }
        }
        if gpu_open && best.map(|(bc, _)| gpu_clock < bc).unwrap_or(true) {
            best = Some((gpu_clock, ranks));
        }
        let Some((_, actor)) = best else { break };
        if actor == ranks {
            match queue.claim_head_work(target, queue.len()) {
                Some(r) => {
                    let w = queue.range_work(r.clone()) as f64;
                    let (e, tr, f) = (
                        w / gpu_speed,
                        w * transfer_frac / gpu_speed,
                        w * filter_frac / gpu_speed,
                    );
                    let exec_start = gpu_clock;
                    // master-side cost per depth: sync pays everything,
                    // two-stage keeps the copy, three-stage execs alone
                    let exec_end = exec_start
                        + match depth {
                            1 => e + tr + f,
                            2 => e + tr,
                            _ => e,
                        };
                    exec_free = exec_end;
                    if depth == 3 {
                        let transfer_end = exec_end.max(transfer_free) + tr;
                        transfer_free = transfer_end;
                        let filter_end = transfer_end.max(filter_free) + f;
                        filter_free = filter_end;
                        stage_filter_end[claim_idx % 3] = filter_end;
                    } else if depth == 2 {
                        let filter_end = exec_end.max(filter_free) + f;
                        filter_free = filter_end;
                        stage_filter_end[claim_idx % 2] = filter_end;
                    } else {
                        transfer_free = exec_end;
                        filter_free = exec_end;
                    }
                    claim_idx += 1;
                    gpu_queries += r.len();
                    // claim-ahead sizing reads the master-side rate each
                    // mode observes before the claim's downstream stages
                    // complete
                    let gpu_rate = match depth {
                        1 => gpu_speed / (1.0 + transfer_frac + filter_frac),
                        2 => gpu_speed / (1.0 + transfer_frac),
                        _ => gpu_speed,
                    };
                    target = next_batch_work(
                        queue.head_work_remaining(queue.len()),
                        gpu_rate,
                        cpu_speed * ranks as f64,
                    );
                }
                None => gpu_open = false,
            }
        } else {
            match queue.claim_tail(chunk) {
                Some(r) => {
                    let w = queue.range_work(r.clone());
                    cpu_clocks[actor] += w as f64 / cpu_speed;
                    cpu_queries += r.len();
                }
                None => cpu_open[actor] = false,
            }
        }
    }
    let cpu_finish = cpu_clocks.iter().cloned().fold(0.0, f64::max);
    let gpu_finish = filter_free.max(transfer_free).max(exec_free);
    let makespan = cpu_finish.max(gpu_finish);
    let idle_frac = if makespan > 0.0 {
        (makespan - cpu_finish.min(gpu_finish)) / makespan
    } else {
        0.0
    };
    Sim { makespan, idle_frac, gpu_queries, cpu_queries }
}

/// The static split in virtual time: each side gets its fixed share up
/// front. Within the CPU the dynamic chunk scheduler balances ranks
/// near-perfectly (PR 1), so the CPU finishes at W_cpu / (ranks x speed).
fn simulate_static(
    d: &Dataset,
    grid: &GridIndex,
    k: usize,
    gamma: f64,
    rho: f64,
    gpu_speed: f64,
    cpu_speed: f64,
    ranks: usize,
) -> Sim {
    let s = split_work(d, grid, k, gamma, rho, true);
    let work_of = |qs: &[u32]| -> u64 {
        // self-join accounting: O(1) memoized adjacent population per id
        qs.iter()
            .map(|&q| grid.adjacent_population_of_id(q).max(1) as u64)
            .sum()
    };
    let (wg, wc) = (work_of(&s.q_gpu), work_of(&s.q_cpu));
    let t_gpu = wg as f64 / gpu_speed;
    let t_cpu = wc as f64 / (cpu_speed * ranks as f64);
    let makespan = t_gpu.max(t_cpu);
    Sim {
        makespan,
        idle_frac: if makespan > 0.0 {
            (makespan - t_gpu.min(t_cpu)) / makespan
        } else {
            0.0
        },
        gpu_queries: s.q_gpu.len(),
        cpu_queries: s.q_cpu.len(),
    }
}

/// The headline scheduling claim: on a skewed (clustered) dataset, the
/// dynamic queue's worst per-architecture idle tail is a fraction of the
/// static split's, across the whole γ sweep - a mispredicted γ cannot
/// strand an architecture because the fronts keep moving until they meet.
#[test]
fn dynamic_queue_shrinks_idle_tail_on_skewed_chist() {
    let d = chist_like(2500).generate(0xD15C);
    // small ε relative to the data spread keeps cell populations low, so
    // high γ thresholds are unreachable - the classic misprediction
    let eps = EpsilonSelector::default().select_host(&d, 5, 0.0).eps;
    let grid = GridIndex::build(&d, 6, eps);
    let queries: Vec<u32> = (0..d.len() as u32).collect();
    let (k, ranks, chunk) = (5, 3, 32);
    // balanced hardware: the device matches the aggregate CPU throughput,
    // so any idle tail is pure scheduling error
    let (gpu_speed, cpu_speed) = (3000.0, 1000.0);

    let mut worst_static = 0.0f64;
    let mut dyn_at_worst = 0.0f64;
    for gamma in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let stat = simulate_static(&d, &grid, k, gamma, 0.0, gpu_speed, cpu_speed, ranks);
        let queue = build_queue(&d, &grid, &queries, k, gamma, 0.0, true);
        let dy = simulate_dynamic(&queue, gpu_speed, cpu_speed, ranks, chunk);
        // every query is computed exactly once under either schedule
        assert_eq!(dy.gpu_queries + dy.cpu_queries, d.len(), "γ={gamma}");
        assert_eq!(stat.gpu_queries + stat.cpu_queries, d.len());
        // the dynamic queue is never meaningfully worse (the margin covers
        // cell-granular claim rounding at the meet point)...
        assert!(
            dy.idle_frac <= stat.idle_frac + 0.15,
            "γ={gamma}: dynamic idle {:.3} vs static {:.3}",
            dy.idle_frac,
            stat.idle_frac
        );
        assert!(dy.makespan > 0.0);
        if stat.idle_frac > worst_static {
            worst_static = stat.idle_frac;
            dyn_at_worst = dy.idle_frac;
        }
    }
    // ...and where the static γ mispredicts worst, the queue collapses the
    // idle tail
    assert!(
        worst_static > 0.15,
        "sweep should contain a mispredicted γ (worst static idle {worst_static:.3})"
    );
    assert!(
        dyn_at_worst < worst_static * 0.5,
        "dynamic idle {dyn_at_worst:.3} should halve the static worst {worst_static:.3}"
    );
}

/// Same harness on near-uniform data: the dynamic queue must not regress
/// where the static split was already fine.
#[test]
fn dynamic_queue_no_worse_on_uniform_susy() {
    let d = susy_like(2000).generate(0x5EED);
    let grid = GridIndex::build(&d, 6, 2.5);
    let queries: Vec<u32> = (0..d.len() as u32).collect();
    for gamma in [0.0, 0.5] {
        let stat = simulate_static(&d, &grid, 5, gamma, 0.0, 2000.0, 1000.0, 2);
        let queue = build_queue(&d, &grid, &queries, 5, gamma, 0.0, true);
        let dy = simulate_dynamic(&queue, 2000.0, 1000.0, 2, 16);
        assert!(
            dy.idle_frac <= stat.idle_frac + 0.15,
            "γ={gamma}: {:.3} vs {:.3}",
            dy.idle_frac,
            stat.idle_frac
        );
    }
}

/// The pipelined-GPU variant of the load-imbalance study: overlapping
/// device exec with host filtering makes the GPU front effectively
/// faster, which must shorten (never lengthen) the join and must not
/// starve the CPU ranks of their tail chunks under density skew.
#[test]
fn pipelined_gpu_overlap_does_not_starve_cpu_tail() {
    let d = chist_like(2500).generate(0xD15C);
    let eps = EpsilonSelector::default().select_host(&d, 5, 0.0).eps;
    let grid = GridIndex::build(&d, 6, eps);
    let queries: Vec<u32> = (0..d.len() as u32).collect();
    let (k, ranks, chunk) = (5, 3, 32);
    // balanced hardware with an expensive filter stage: 80% of exec -
    // exactly what the pipeline exists to hide
    let (gpu_speed, cpu_speed, filter_frac) = (3000.0, 1000.0, 0.8);

    for (gamma, rho) in [(0.0, 0.2), (0.5, 0.2)] {
        let q_sync = build_queue(&d, &grid, &queries, k, gamma, rho, true);
        let sync = simulate_overlap(
            &q_sync, gpu_speed, 0.0, filter_frac, cpu_speed, ranks, chunk, 1,
        );
        let q_pipe = build_queue(&d, &grid, &queries, k, gamma, rho, true);
        let pipe = simulate_overlap(
            &q_pipe, gpu_speed, 0.0, filter_frac, cpu_speed, ranks, chunk, 2,
        );

        // every query computed exactly once under both drains
        assert_eq!(sync.gpu_queries + sync.cpu_queries, d.len(), "γ={gamma}");
        assert_eq!(pipe.gpu_queries + pipe.cpu_queries, d.len(), "γ={gamma}");
        // no starvation: the CPU keeps the ρ reserve plus a real share of
        // the open middle even though the overlapped GPU claims faster
        assert!(
            pipe.cpu_queries >= q_pipe.reserve(),
            "γ={gamma}: CPU lost its ρ reserve ({} < {})",
            pipe.cpu_queries,
            q_pipe.reserve()
        );
        assert!(
            pipe.cpu_queries > q_pipe.reserve(),
            "γ={gamma}: overlap starved the CPU of the open middle"
        );
        // a faster effective GPU must never be worse, and the overlap
        // must not blow up the per-architecture idle tail
        assert!(
            pipe.makespan <= sync.makespan * 1.02,
            "γ={gamma}: pipelined makespan {:.4} vs sync {:.4}",
            pipe.makespan,
            sync.makespan
        );
        assert!(
            pipe.idle_frac <= sync.idle_frac + 0.15,
            "γ={gamma}: pipelined idle {:.3} vs sync {:.3}",
            pipe.idle_frac,
            sync.idle_frac
        );
    }

    // GPU-heavy regime (one slow CPU rank): the join is GPU-bound, so
    // hiding the filter stage must shorten the makespan materially
    let q_sync = build_queue(&d, &grid, &queries, k, 0.0, 0.0, true);
    let sync = simulate_overlap(&q_sync, 3000.0, 0.0, 0.9, 100.0, 1, 32, 1);
    let q_pipe = build_queue(&d, &grid, &queries, k, 0.0, 0.0, true);
    let pipe = simulate_overlap(&q_pipe, 3000.0, 0.0, 0.9, 100.0, 1, 32, 2);
    assert!(
        pipe.makespan < sync.makespan * 0.8,
        "overlap should hide most of the filter stage: {:.4} vs {:.4}",
        pipe.makespan,
        sync.makespan
    );
}

/// The transfer stage's reason to exist: when the join is GPU-bound and
/// the device-to-host copy is a large fraction of exec, moving the copy
/// off the master thread must shorten the makespan by about the copy
/// time - the two-stage master pays exec + transfer serially per unit of
/// work, the three-stage master pays exec alone, with transfer AND
/// filter both hidden behind the device.
#[test]
fn three_stage_hides_transfer_in_gpu_bound_regime() {
    let d = chist_like(2500).generate(0xD15C);
    let eps = EpsilonSelector::default().select_host(&d, 5, 0.0).eps;
    let grid = GridIndex::build(&d, 6, eps);
    let queries: Vec<u32> = (0..d.len() as u32).collect();
    let (k, ranks, chunk) = (5, 1, 32);
    // GPU-bound: one slow CPU rank; heavy copy (60% of exec) and a
    // moderate filter (30%) - both individually smaller than exec, so a
    // perfect pipeline hides them entirely
    let (gpu_speed, cpu_speed) = (3000.0, 100.0);
    let (transfer_frac, filter_frac) = (0.6, 0.3);

    let run = |depth: usize| {
        let q = build_queue(&d, &grid, &queries, k, 0.0, 0.0, true);
        simulate_overlap(
            &q, gpu_speed, transfer_frac, filter_frac, cpu_speed, ranks, chunk,
            depth,
        )
    };
    let sync = run(1);
    let two = run(2);
    let three = run(3);

    // every query computed exactly once under all three drains
    for s in [&sync, &two, &three] {
        assert_eq!(s.gpu_queries + s.cpu_queries, d.len());
    }
    // the two-stage drain already hides the filter...
    assert!(
        two.makespan < sync.makespan,
        "two-stage {:.4} vs sync {:.4}",
        two.makespan,
        sync.makespan
    );
    // ...and the dedicated transfer stage hides most of the copy on top:
    // the GPU-bound makespan should drop by roughly transfer_frac /
    // (1 + transfer_frac) (~37% here); assert a conservative 15% so the
    // test stays robust to claim-tail and CPU-share effects
    assert!(
        three.makespan < two.makespan * 0.85,
        "transfer stage not hidden: three-stage {:.4} vs two-stage {:.4}",
        three.makespan,
        two.makespan
    );
    // and a deeper pipeline must never be worse than a shallower one
    assert!(three.makespan <= sync.makespan, "three-stage regressed past sync");
}

/// Concurrent (real threads) two-ended drain with Q^Fail recirculation
/// over a queue built from a real grid: every query is claimed exactly
/// once, recirculated failures are absorbed exactly once, and the ρ
/// reserve stays CPU-owned. This is the integration-level version of the
/// `sched::queue` unit property tests.
#[test]
fn concurrent_drain_with_recirc_partitions_queries() {
    use std::sync::atomic::{AtomicUsize, Ordering};

    prop::cases(6, 0xC1A1, |rng| {
        let n = 500 + rng.below(1500);
        let d = susy_like(n).generate(rng.next_u64());
        let grid = GridIndex::build(&d, 6, 1.5 + rng.f64() * 2.0);
        let queries: Vec<u32> = (0..d.len() as u32).collect();
        let gamma = rng.f64();
        let rho = rng.f64() * 0.5;
        let queue = build_queue(&d, &grid, &queries, 4, gamma, rho, true);
        let ranks = 1 + rng.below(3);
        let chunk = 8 + rng.below(32);
        let solved: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let reserve = queue.reserve();

        std::thread::scope(|scope| {
            // fake GPU master: claims head batches, fails every 5th query
            // into the recirculation buffer, "solves" the rest
            {
                let (queue, solved) = (&queue, &solved);
                scope.spawn(move || {
                    let mut target = first_batch_work(
                        queue.head_work_remaining(queue.len()),
                        queue.dense_work(),
                    );
                    while let Some(r) = queue.claim_head_work(target, queue.len()) {
                        let mut failed = Vec::new();
                        for (i, &q) in queue.query_slice(r.clone()).iter().enumerate() {
                            if i % 5 == 4 {
                                failed.push(q);
                            } else {
                                solved[q as usize].fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        queue.push_failed(&failed);
                        target = next_batch_work(
                            queue.head_work_remaining(queue.len()),
                            1.0,
                            1.0,
                        );
                    }
                    queue.set_gpu_done();
                });
            }
            // CPU ranks: tail + recirc until everything is drained
            for _ in 0..ranks {
                let (queue, solved) = (&queue, &solved);
                scope.spawn(move || loop {
                    if let Some(r) = queue.claim_tail(chunk) {
                        for &q in queue.query_slice(r) {
                            solved[q as usize].fetch_add(1, Ordering::Relaxed);
                        }
                        continue;
                    }
                    if let Some(ids) = queue.claim_recirc(chunk) {
                        for q in ids {
                            solved[q as usize].fetch_add(1, Ordering::Relaxed);
                        }
                        continue;
                    }
                    if queue.gpu_done() {
                        if let Some(ids) = queue.claim_recirc(chunk) {
                            for q in ids {
                                solved[q as usize].fetch_add(1, Ordering::Relaxed);
                            }
                            continue;
                        }
                        break;
                    }
                    std::thread::yield_now();
                });
            }
        });

        // failed queries were solved once by the CPU, everything else
        // once by whoever claimed it
        assert!(
            solved.iter().all(|s| s.load(Ordering::Relaxed) == 1),
            "every query solved exactly once (n={n} γ={gamma:.2} ρ={rho:.2})"
        );
        assert_eq!(queue.claimed_head() + queue.claimed_tail(), n);
        assert!(queue.claimed_tail() >= reserve, "ρ reserve is CPU-owned");
    });
}

/// The retry backoff is a bounded exponential: doubling per attempt from
/// the base, clamped at the cap, and degenerate (zero) bases stay zero -
/// the shape the GPU master sleeps on between claim retries.
#[test]
fn retry_backoff_is_bounded_exponential() {
    let p = RecoveryPolicy::default();
    assert_eq!(p.backoff_secs(0), p.backoff_base_secs);
    assert_eq!(p.backoff_secs(1), p.backoff_base_secs * 2.0);
    assert_eq!(p.backoff_secs(2), p.backoff_base_secs * 4.0);
    // monotone non-decreasing up to the cap, then flat
    let mut last = 0.0;
    for a in 0..40 {
        let b = p.backoff_secs(a);
        assert!(b >= last, "attempt {a}: backoff must not shrink");
        assert!(b <= p.backoff_cap_secs, "attempt {a}: cap violated");
        last = b;
    }
    assert_eq!(p.backoff_secs(30), p.backoff_cap_secs);
    // a zeroed base (the test configuration) never sleeps
    let mut q = p;
    q.backoff_base_secs = 0.0;
    assert_eq!(q.backoff_secs(7), 0.0);
}

/// Graceful degradation at the scheduling layer, deterministically: a GPU
/// master that reclaims its current claim and stops mid-head (the demoted
/// master's exit) leaves a queue the CPU ranks fully absorb - abandoned
/// head work via tail claims, the reclaimed queries via recirculation -
/// with the exactly-once partition intact.
#[test]
fn demoted_master_leaves_a_drainable_queue() {
    use std::sync::atomic::{AtomicUsize, Ordering};

    prop::cases(6, 0xDE6A, |rng| {
        let n = 500 + rng.below(1000);
        let d = susy_like(n).generate(rng.next_u64());
        let grid = GridIndex::build(&d, 6, 1.5 + rng.f64() * 2.0);
        let queries: Vec<u32> = (0..d.len() as u32).collect();
        let queue =
            build_queue(&d, &grid, &queries, 4, rng.f64(), rng.f64() * 0.3, true);
        let ranks = 1 + rng.below(3);
        let chunk = 8 + rng.below(24);
        // the master survives 0..3 claims before its "device" dies
        let good_claims = rng.below(4);
        let solved: Vec<AtomicUsize> =
            (0..n).map(|_| AtomicUsize::new(0)).collect();
        let mut reclaimed = 0usize;

        std::thread::scope(|scope| {
            {
                let (queue, solved) = (&queue, &solved);
                let reclaimed = &mut reclaimed;
                scope.spawn(move || {
                    let mut target = first_batch_work(
                        queue.head_work_remaining(queue.len()),
                        queue.dense_work(),
                    );
                    let mut done = 0usize;
                    while let Some(r) = queue.claim_head_work(target, queue.len())
                    {
                        if done == good_claims {
                            // the demotion path: the failed claim's queries
                            // recirculate, the master abandons the head
                            let qs = queue.query_slice(r.clone()).to_vec();
                            *reclaimed = qs.len();
                            queue.push_failed(&qs);
                            break;
                        }
                        for &q in queue.query_slice(r.clone()) {
                            solved[q as usize].fetch_add(1, Ordering::Relaxed);
                        }
                        done += 1;
                        target = next_batch_work(
                            queue.head_work_remaining(queue.len()),
                            1.0,
                            queue.cpu_work_rate(),
                        );
                    }
                    queue.set_gpu_done();
                });
            }
            for _ in 0..ranks {
                let (queue, solved) = (&queue, &solved);
                scope.spawn(move || loop {
                    let done = queue.gpu_done();
                    if let Some(r) = queue.claim_tail(chunk) {
                        for &q in queue.query_slice(r) {
                            solved[q as usize].fetch_add(1, Ordering::Relaxed);
                        }
                        continue;
                    }
                    if let Some(ids) = queue.claim_recirc(chunk) {
                        for q in ids {
                            solved[q as usize].fetch_add(1, Ordering::Relaxed);
                        }
                        continue;
                    }
                    if done {
                        break;
                    }
                    std::thread::yield_now();
                });
            }
        });

        for (q, s) in solved.iter().enumerate() {
            assert_eq!(
                s.load(Ordering::Relaxed),
                1,
                "query {q} resolved {} times after demotion (n={n} \
                 good_claims={good_claims})",
                s.load(Ordering::Relaxed)
            );
        }
        assert_eq!(queue.claimed_head() + queue.claimed_tail(), n);
        assert_eq!(queue.recirc_pushed(), reclaimed, "reclaim published once");
    });
}

/// γ/ρ reinterpretation sanity: the dense prefix shrinks monotonically in
/// γ (it is the static Q^GPU) and the reserve is exactly the ρ floor.
#[test]
fn gamma_and_rho_seed_the_queue_monotonically() {
    let d = susy_like(1800).generate(77);
    let grid = GridIndex::build(&d, 6, 2.2);
    let queries: Vec<u32> = (0..d.len() as u32).collect();
    let mut last = usize::MAX;
    for gamma in [0.0, 0.3, 0.6, 1.0] {
        let q = build_queue(&d, &grid, &queries, 5, gamma, 0.25, true);
        assert!(q.dense_prefix() <= last, "γ must shrink the dense prefix");
        last = q.dense_prefix();
        assert_eq!(q.reserve(), (0.25f64 * d.len() as f64).ceil() as usize);
        assert_eq!(q.len(), d.len());
    }
}
