//! R ⋈_KNN S bipartite join: correctness against a brute-force oracle and
//! semantic differences from the self-join (no self-exclusion).

use hybrid_knn_join::core::sqdist;
use hybrid_knn_join::prelude::*;

fn brute_rs(r: &Dataset, s: &Dataset, q: usize, k: usize) -> Vec<f64> {
    let mut d: Vec<f64> = (0..s.len()).map(|j| sqdist(r.point(q), s.point(j))).collect();
    d.sort_by(|a, b| a.partial_cmp(b).unwrap());
    d.truncate(k);
    d
}

#[test]
fn hybrid_rs_matches_bruteforce() {
    let engine = Engine::load_default().unwrap();
    let r = susy_like(400).generate(201);
    let s = susy_like(900).generate(202);
    let mut p = HybridParams::new(4);
    p.cpu_ranks = 2;
    p.gamma = 0.3;
    let rep = HybridKnnJoin::run_rs(&engine, &r, &s, &p).unwrap();
    assert_eq!(rep.q_gpu + rep.q_cpu, r.len());
    assert_eq!(rep.result.solved_count(4), r.len());
    for q in (0..r.len()).step_by(41) {
        let got = rep.result.get(q);
        let want = brute_rs(&r, &s, q, 4);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g.dist2 - w).abs() < 1e-3 * (1.0 + w),
                "q={q}: {} vs {w}",
                g.dist2
            );
        }
        // neighbor ids must index S
        for n in got {
            assert!((n.id as usize) < s.len());
        }
    }
}

#[test]
fn rs_join_keeps_identical_points() {
    // a point of R that exists in S must match itself at distance 0
    // (no self-exclusion in the bipartite join)
    let engine = Engine::load_default().unwrap();
    let s = susy_like(500).generate(203);
    let r = s.gather(&[7, 13, 99]);
    let mut p = HybridParams::new(1);
    p.cpu_ranks = 1;
    let rep = HybridKnnJoin::run_rs(&engine, &r, &s, &p).unwrap();
    for q in 0..r.len() {
        let n = rep.result.get(q).at(0);
        // device-path distances use the matmul formulation: self-distance
        // carries O(|x|^2 * eps_f32) cancellation noise, not exact zero
        assert!(n.dist2 < 0.05, "query {q} should find its twin: {n:?}");
    }
}

#[test]
fn self_join_excludes_self_but_rs_does_not() {
    let engine = Engine::load_default().unwrap();
    let d = susy_like(300).generate(204);
    let mut p = HybridParams::new(1);
    p.cpu_ranks = 1;
    let selfj = HybridKnnJoin::run(&engine, &d, &p).unwrap();
    let rs = HybridKnnJoin::run_rs(&engine, &d, &d, &p).unwrap();
    let mut self_hits = 0;
    for q in 0..d.len() {
        // matmul-formulation noise on the device path (see above)
        assert!(rs.result.get(q).at(0).dist2 < 0.05);
        if selfj.result.get(q).at(0).id == q as u32 {
            self_hits += 1;
        }
    }
    assert_eq!(self_hits, 0, "self-join must never return the query itself");
}

#[test]
fn rs_dimension_mismatch_is_error() {
    let engine = Engine::load_default().unwrap();
    let r = susy_like(50).generate(205);
    let s = chist_like(50).generate(206);
    let p = HybridParams::new(2);
    assert!(HybridKnnJoin::run_rs(&engine, &r, &s, &p).is_err());
}

#[test]
fn gpu_rs_agrees_with_cpu_rs() {
    let engine = Engine::load_default().unwrap();
    let r = susy_like(200).generate(207);
    let s = susy_like(600).generate(208);
    let sel = EpsilonSelector::default()
        .select_rs(&engine, &r, &s, 3, 0.3)
        .unwrap();
    let grid = GridIndex::build(&s, 6, sel.eps);
    let queries: Vec<u32> = (0..r.len() as u32).collect();
    let mut params = GpuJoinParams::new(3, sel.eps);
    params.exclude_self = false;
    let g = gpu_join_rs(&engine, &r, &s, &grid, &queries, &params).unwrap();
    let tree = KdTree::build(&s);
    let c = exact_ann_rs(&s, &tree, &r, &queries, 3, 2, false);
    let mut compared = 0;
    for q in 0..r.len() {
        let gq = g.result.get(q);
        if gq.len() < 3 {
            continue;
        }
        for (a, b) in gq.iter().zip(c.result.get(q)) {
            assert!((a.dist2 - b.dist2).abs() < 1e-3 * (1.0 + b.dist2), "q={q}");
        }
        compared += 1;
    }
    assert!(compared > 0);
}
