//! Streaming-service equivalence and resilience suite (DESIGN.md §11).
//!
//! The load-bearing property: in deterministic replay mode
//! (`cpu_ranks == 0`) a resident [`KnnEngine`] serving N concurrent
//! client sessions over an *arbitrary* interleaving of query
//! micro-batches is bit-identical to the one-shot batch join on the
//! union of their queries, across all three `DrainMode`s - each query's
//! result is a pure function of (corpus, ε, k), independent of how the
//! stream was chopped into flushes. Exactly-once accounting is checked
//! alongside: every submitted query is answered once, and head/tail
//! claims partition every flush.
//!
//! Also here: the production-config (concurrent CPU ranks) streaming
//! path checked exact against the kd-tree, and the lock-poisoning
//! regression - a caught filter panic inside one flush must not brick
//! the session's later flushes (recovered pools, unpoisoned engine
//! cache, reusable drain arenas).

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::rng::Rng;

/// Drive `queries` through a fresh resident engine with `n_clients`
/// concurrent client sessions: client c owns the strided slice
/// {c, c+n, c+2n, ...} of the query set, chopped into seeded-random
/// request chunks, so coalesced micro-batch composition varies with
/// thread interleaving while the union stays fixed. Returns every
/// (global query ids, reply) pair plus the service report.
fn run_streamed(
    engine: &Engine,
    corpus: &Dataset,
    queries: &Dataset,
    params: &HybridParams,
    n_clients: usize,
    seed: u64,
) -> (Vec<(Vec<usize>, BatchReply)>, ServiceReport) {
    let mut session =
        KnnEngine::build(engine, corpus, params.clone()).unwrap();
    let mut rng = Rng::new(seed);
    let mut plans: Vec<Vec<Vec<usize>>> = Vec::new();
    for c in 0..n_clients {
        let ids: Vec<usize> = (c..queries.len()).step_by(n_clients).collect();
        let mut chunks = Vec::new();
        let mut i = 0usize;
        while i < ids.len() {
            let take = (1 + rng.below(17)).min(ids.len() - i);
            chunks.push(ids[i..i + take].to_vec());
            i += take;
        }
        plans.push(chunks);
    }
    let ingress = Ingress::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = plans
            .iter()
            .map(|chunks| {
                let client = ingress.client();
                s.spawn(move || {
                    let mut out = Vec::new();
                    for chunk in chunks {
                        let reply = client
                            .query(&queries.gather(chunk))
                            .expect("service replied");
                        out.push((chunk.clone(), reply));
                    }
                    out
                })
            })
            .collect();
        let report = session.serve(&ingress).unwrap();
        let mut replies = Vec::new();
        for h in handles {
            replies.extend(h.join().expect("client thread panicked"));
        }
        (replies, report)
    })
}

#[test]
fn streaming_bit_identical_to_batch_union_across_drain_modes() {
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(900).generate(0x31);
    let queries = susy_like(360).generate(0x77);
    for (i, mode) in [DrainMode::Sync, DrainMode::TwoStage, DrainMode::ThreeStage]
        .into_iter()
        .enumerate()
    {
        let mut p = HybridParams::new(4);
        p.cpu_ranks = 0; // deterministic replay mode
        p.gpu_drain = mode;
        p.streams = 2;
        p.buffer_pairs = 50_000; // several claims per non-trivial flush
        // one-shot batch reference: the whole union in a single flush
        let mut ref_session =
            KnnEngine::build(&engine, &corpus, p.clone()).unwrap();
        let (ref_result, ref_rep) = ref_session.flush(&queries).unwrap();
        assert_eq!(
            ref_rep.q_gpu,
            queries.len(),
            "deterministic mode drains everything through the GPU head"
        );
        assert_eq!(ref_rep.solved_on_gpu + ref_rep.q_fail, ref_rep.q_gpu);

        let (replies, report) = run_streamed(
            &engine, &corpus, &queries, &p, 3, 0xC0FFEE ^ (i as u64) << 8,
        );
        // exactly-once accounting over the whole stream
        assert_eq!(report.queries, queries.len(), "{mode:?}: queries served");
        assert_eq!(
            report.q_gpu + report.q_cpu,
            queries.len(),
            "{mode:?}: head/tail claims partition the stream"
        );
        assert_eq!(report.q_gpu, queries.len(), "{mode:?}: GPU-first replay");
        assert_eq!(report.requests, replies.len());
        assert!(report.flushes >= 1);
        assert!(report.latency_p99 >= report.latency_p50);
        assert!(report.throughput_qps > 0.0);
        // the default (fully permissive) policy never rejects or
        // sheds: the admission ledger says every row was served
        assert_eq!(report.admitted, queries.len(), "{mode:?}: admitted");
        assert_eq!(
            report.shed_overload + report.shed_quota + report.shed_deadline,
            0,
            "{mode:?}: nothing shed under the default policy"
        );
        assert_eq!(report.rejected_requests, 0, "{mode:?}");

        let mut seen = vec![false; queries.len()];
        for (ids, reply) in &replies {
            assert_eq!(ids.len(), reply.results.len(), "{mode:?}: reply shape");
            for (j, &g) in ids.iter().enumerate() {
                assert!(!seen[g], "{mode:?}: q={g} answered twice");
                seen[g] = true;
                let want = ref_result.get(g);
                let got = &reply.results[j];
                assert_eq!(
                    got.ids.as_slice(),
                    want.ids(),
                    "{mode:?} q={g}: id lane"
                );
                assert_eq!(
                    got.dist2.as_slice(),
                    want.dist2s(),
                    "{mode:?} q={g}: dist² lane"
                );
            }
        }
        assert!(
            seen.iter().all(|&b| b),
            "{mode:?}: every query answered exactly once"
        );
    }
}

#[test]
fn production_streaming_config_is_exact() {
    // concurrent CPU ranks + live dense/sparse split per flush: results
    // are exact (vs the kd-tree) though which side computes each query -
    // and hence the f32-device vs f64-host rounding - varies per run
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(800).generate(0x41);
    let queries = susy_like(240).generate(0x42);
    let mut p = HybridParams::new(5);
    p.cpu_ranks = 2;
    let (replies, report) =
        run_streamed(&engine, &corpus, &queries, &p, 2, 0xFEED);
    assert_eq!(report.queries, queries.len());
    assert_eq!(report.q_gpu + report.q_cpu, queries.len());
    let tree = KdTree::build(&corpus);
    let mut answered = 0usize;
    for (ids, reply) in &replies {
        for (j, &g) in ids.iter().enumerate() {
            answered += 1;
            let want = tree.knn(&corpus, queries.point(g), 5, u32::MAX);
            let got = &reply.results[j];
            assert_eq!(got.ids.len(), want.len(), "q={g}: neighbor count");
            for (d, w) in got.dist2.iter().zip(&want) {
                // the session's variance REORDER permutes summation
                // order, so exactness is up to relative f64 rounding
                assert!(
                    (d - w.dist2).abs() < 1e-3 * (1.0 + w.dist2),
                    "q={g}: {d} vs {}",
                    w.dist2
                );
            }
        }
    }
    assert_eq!(answered, queries.len());
}

#[test]
fn caught_filter_panic_does_not_brick_the_resident_session() {
    // the lock-poisoning regression: a filter worker panic in flush 1 is
    // caught and recovered claim-scoped; the same session's pools,
    // engine executable cache, and drain arenas must then serve flush 2
    // as if nothing happened (no poisoned-mutex panics anywhere)
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(700).generate(0x21);
    let mut p = HybridParams::new(4);
    p.cpu_ranks = 0; // route every query through the GPU master
    p.fault =
        FaultPlan::one(FaultSpec::transient(FaultKind::FilterPanic, 0, 0));
    p.recovery.backoff_base_secs = 0.0;
    let mut session = KnnEngine::build(&engine, &corpus, p).unwrap();
    let q1 = susy_like(160).generate(0x22);
    let (r1, rep1) = session.flush(&q1).unwrap();
    assert_eq!(
        r1.solved_count(4),
        q1.len(),
        "flush 1 completes despite the injected panic"
    );
    assert!(rep1.gpu_faults >= 1, "the injected filter panic was observed");
    let q2 = susy_like(160).generate(0x23);
    let (r2, rep2) = session.flush(&q2).unwrap();
    assert_eq!(r2.solved_count(4), q2.len(), "flush 2 not bricked");
    assert_eq!(rep2.queries, q2.len());
    assert_eq!(session.flushes(), 2);
}

#[test]
fn empty_and_tiny_requests_are_served() {
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(500).generate(0x61);
    let mut p = HybridParams::new(3);
    p.cpu_ranks = 0;
    let mut session = KnnEngine::build(&engine, &corpus, p).unwrap();
    let dims = session.dims();
    let queries = susy_like(8).generate(0x62);
    let ingress = Ingress::new();
    std::thread::scope(|s| {
        let client = ingress.client();
        let h = s.spawn(move || {
            let empty = Dataset::new(Vec::new(), dims);
            let r0 = client.query(&empty).unwrap();
            assert!(r0.results.is_empty());
            let r1 = client.query(&queries.gather(&[0])).unwrap();
            assert_eq!(r1.results.len(), 1);
            assert_eq!(r1.results[0].ids.len(), 3);
            assert_eq!(r1.results[0].dist2.len(), 3);
            assert!(r1.latency_secs >= 0.0);
        });
        let rep = session.serve(&ingress).unwrap();
        h.join().expect("client thread panicked");
        assert_eq!(rep.queries, 1);
        assert_eq!(rep.requests, 2);
        assert!(rep.flushes >= 1);
    });
}

/// Pre-queue `n_early` single-query requests, then one late request, and
/// serve with the given flush cap (0 = leave the default uncapped).
/// Returns (late reply, early replies' flush_seqs, report). The backlog
/// is fully enqueued - sequenced via [`Ingress::pending_len`] - before
/// the serve loop starts, so flush composition is deterministic.
fn serve_backlog_then_late(
    session: &mut KnnEngine<'_>,
    queries: &Dataset,
    n_early: usize,
    cap: usize,
) -> (BatchReply, Vec<usize>, ServiceReport) {
    if cap > 0 {
        session.set_flush_cap(cap);
    }
    let ingress = Ingress::new();
    std::thread::scope(|s| {
        let early: Vec<_> = (0..n_early)
            .map(|i| {
                let client = ingress.client();
                s.spawn(move || {
                    client.query(&queries.gather(&[i])).unwrap().flush_seq
                })
            })
            .collect();
        while ingress.pending_len() < n_early {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let late_client = ingress.client();
        let late = s.spawn(move || {
            late_client.query(&queries.gather(&[n_early])).unwrap()
        });
        while ingress.pending_len() < n_early + 1 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let rep = session.serve(&ingress).unwrap();
        let late_reply = late.join().expect("late client panicked");
        let seqs =
            early.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>();
        (late_reply, seqs, rep)
    })
}

#[test]
fn flush_cap_bounds_late_client_latency() {
    // ISSUE 9 satellite: a late client queued behind a backlog must land
    // within two flushes once the flush cap slices the backlog - and the
    // capped replies stay bit-identical to the uncapped coalesced flush.
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(500).generate(0x91);
    let queries = susy_like(4).generate(0x92);
    let mut p = HybridParams::new(3);
    p.cpu_ranks = 0; // deterministic replay mode
    let mut ref_session = KnnEngine::build(&engine, &corpus, p.clone()).unwrap();
    let (ref_result, _) = ref_session.flush(&queries).unwrap();

    // uncapped control: the whole backlog coalesces into one flush and
    // the late client rides it (flush_seq 0)
    let mut session = KnnEngine::build(&engine, &corpus, p.clone()).unwrap();
    let (late, seqs, rep) = serve_backlog_then_late(&mut session, &queries, 3, 0);
    assert_eq!(rep.flushes, 1, "uncapped: one coalesced flush");
    assert_eq!(rep.max_flush_queries, 4);
    assert_eq!(late.flush_seq, 0);
    assert!(seqs.iter().all(|&s| s == 0));

    // cap 2 over the same 3+1 backlog: deterministic [2, 2] slicing; the
    // late request lands in flush 1 - within two flushes of serve start
    let mut session = KnnEngine::build(&engine, &corpus, p).unwrap();
    let (late, seqs, rep) = serve_backlog_then_late(&mut session, &queries, 3, 2);
    assert_eq!(rep.flushes, 2, "capped: backlog sliced into two flushes");
    assert_eq!(rep.max_flush_queries, 2, "no flush exceeds the cap");
    assert_eq!(rep.queries, 4);
    assert_eq!(rep.requests, 4);
    assert_eq!(
        late.flush_seq, 1,
        "late client lands within two flushes despite the backlog"
    );
    assert!(seqs.iter().all(|&s| s <= 1));
    // capped result is still the pure function of (corpus, eps, k)
    let want = ref_result.get(3);
    assert_eq!(late.results.len(), 1);
    assert_eq!(late.results[0].ids.as_slice(), want.ids(), "id lane");
    assert_eq!(late.results[0].dist2.as_slice(), want.dist2s(), "dist2 lane");
}
