//! Drain-mode equivalence: the pipelined GPU drains (two-stage: device
//! exec of claim i+1 overlapping host filtering of claim i; three-stage:
//! exec of claim i+1, device-to-host transfer of claim i, and filtering
//! of claim i-1 all overlapping) must be *invisible* in the output -
//! bit-identical `KnnResult` slots and the same solved/failed partition
//! as the synchronous drain, on every workload shape and staging
//! configuration.
//!
//! Why bit-identity is the right bar: with no CPU ranks draining the
//! tail, claim sizing is deterministic (the CPU rate is 0, so the sizing
//! policy takes its evidence-free 0.5 branch), and within a claim each
//! query's candidate pushes arrive in candidate order regardless of flush
//! round boundaries - so all three drains must agree to the last bit, and
//! any divergence is a real pipeline bug (aliased arena slot, lost round,
//! mis-ordered resolve, transfer-stage reordering), not numeric noise.
//! (In production the modes may draw different claim *boundaries* - the
//! sync drain sizes from its total busy rate, the pipelined drains from
//! the kernel-only rate against a live CPU rate - but results stay
//! identical there too: a query's pushes arrive in candidate order
//! within whatever claim it lands in.)

use hybrid_knn_join::gpu::join::gpu_join_drain;
use hybrid_knn_join::prelude::*;
use hybrid_knn_join::sched::build_queue;

/// Run a GPU-only queue drain over `queries` of `r_data` against `data`
/// (self-join when they are the same dataset) and return the result
/// table, the failed set, and the drain stats.
#[allow(clippy::too_many_arguments)]
fn drain(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    eps: f64,
    k: usize,
    streams: usize,
    buffer_pairs: u64,
    mode: DrainMode,
    exclude_self: bool,
) -> (KnnResult, Vec<u32>, usize) {
    let grid = GridIndex::build(data, 6, eps);
    let queries: Vec<u32> = (0..r_data.len() as u32).collect();
    // id-keyed grouping only when the queries index the grid's dataset
    let queue = build_queue(
        r_data, &grid, &queries, k, 0.0, 0.0, std::ptr::eq(r_data, data),
    );
    let mut params = GpuJoinParams::new(k, eps);
    params.streams = streams;
    params.buffer_pairs = buffer_pairs;
    params.drain = mode;
    params.exclude_self = exclude_self;
    let mut result = KnnResult::new(r_data.len(), k);
    let slots = result.slots();
    let stats = gpu_join_drain(
        engine, r_data, data, &grid, &queue, &params, &slots,
        queue.len(),
    )
    .unwrap();
    drop(slots);
    assert_eq!(
        stats.solved + stats.failed.len(),
        queries.len(),
        "every claimed query resolved exactly once"
    );
    assert_eq!(queue.claimed_head(), queries.len());
    assert_eq!(queue.recirc_pushed(), stats.failed.len());
    (result, stats.failed, stats.batches)
}

/// Bit-identical result tables: same counts, same id lanes, same dist²
/// bits for every query slot.
fn assert_bit_identical(a: &KnnResult, b: &KnnResult, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: table sizes");
    for q in 0..a.len() {
        let (x, y) = (a.get(q), b.get(q));
        assert_eq!(x.len(), y.len(), "{ctx}: q={q} neighbor count");
        assert_eq!(x.ids(), y.ids(), "{ctx}: q={q} id lane");
        assert_eq!(x.dist2s(), y.dist2s(), "{ctx}: q={q} dist² lane");
    }
}

/// The three-way equivalence matrix for one workload: for several
/// streams and buffer settings, the two-stage and three-stage drains
/// must match the synchronous drain bit for bit, including the
/// solved/failed partition.
fn check_workload(
    engine: &Engine,
    name: &str,
    r_data: &Dataset,
    data: &Dataset,
    eps: f64,
    k: usize,
    exclude_self: bool,
) {
    // small buffer forces many claims (deep pipeline); large buffer
    // collapses to few claims (shallow pipeline, resolve-at-end path)
    for &(streams, buffer_pairs) in
        &[(1usize, 3_000u64), (3, 3_000), (2, 10_000_000)]
    {
        let base_ctx = format!("{name} streams={streams} buffer={buffer_pairs}");
        let (sync_res, sync_failed, _) = drain(
            engine, r_data, data, eps, k, streams, buffer_pairs,
            DrainMode::Sync, exclude_self,
        );
        for mode in [DrainMode::TwoStage, DrainMode::ThreeStage] {
            let ctx = format!("{base_ctx} mode={mode:?}");
            let (pipe_res, pipe_failed, pipe_batches) = drain(
                engine, r_data, data, eps, k, streams, buffer_pairs, mode,
                exclude_self,
            );
            assert_eq!(sync_failed, pipe_failed, "{ctx}: Q^Fail partition");
            assert_bit_identical(&sync_res, &pipe_res, &ctx);
            assert!(pipe_batches > 0, "{ctx}: pipelined drain claimed nothing");
        }
    }
}

#[test]
fn pipelined_drains_match_sync_on_uniform_selfjoin() {
    let engine = Engine::load_default().unwrap();
    let data = susy_like(900).generate(0x51DE);
    check_workload(&engine, "susy_uniform", &data, &data, 2.0, 6, true);
}

#[test]
fn pipelined_drains_match_sync_on_skewed_gaussian() {
    // chist-like clustered Gaussian data: dense head cells produce big
    // claims with many flush rounds, plus a long sparse tail of
    // one-query cells - the shape that stresses split tiles, the
    // staging-set rotation, and the transfer stage's lane ordering
    let engine = Engine::load_default().unwrap();
    let data = chist_like(700).generate(0x5E3D);
    let sel = EpsilonSelector::default().select_host(&data, 4, 0.3);
    check_workload(&engine, "chist_skewed", &data, &data, sel.eps, 4, true);
}

#[test]
fn pipelined_drains_match_sync_on_bipartite() {
    // R JOIN S: queries from R, grid + candidates from S, no
    // self-exclusion; R cells with no S candidates exercise empty-claim
    // rounds (a claim whose cells emit no tiles still resolves as all
    // failed, in order, through every pipeline depth)
    let engine = Engine::load_default().unwrap();
    let r = susy_like(400).generate(0xB1);
    let s = susy_like(800).generate(0xB2);
    check_workload(&engine, "bipartite", &r, &s, 2.2, 4, false);
}

#[test]
fn pipelined_drain_overlap_telemetry_is_consistent() {
    // Not a timing assertion (wall-clock overlap is environment
    // dependent) - just the accounting invariants: per-claim
    // exec/transfer/filter components are finite, non-negative, and sum
    // to the claim's service seconds; the stats' totals match the
    // per-claim telemetry; and under the three-stage drain the transfer
    // lane actually carries the copy seconds.
    let engine = Engine::load_default().unwrap();
    let data = susy_like(800).generate(0x0E);
    let grid = GridIndex::build(&data, 6, 2.0);
    let queries: Vec<u32> = (0..data.len() as u32).collect();
    for mode in [DrainMode::TwoStage, DrainMode::ThreeStage] {
        let queue = build_queue(&data, &grid, &queries, 5, 0.0, 0.0, true);
        let mut params = GpuJoinParams::new(5, 2.0);
        params.buffer_pairs = 3_000; // many claims
        params.drain = mode;
        let mut result = KnnResult::new(data.len(), 5);
        let slots = result.slots();
        let stats = gpu_join_drain(
            &engine, &data, &data, &grid, &queue, &params, &slots,
            queue.len(),
        )
        .unwrap();
        drop(slots);
        assert!(!stats.claims.is_empty(), "{mode:?}");
        let (mut exec_sum, mut transfer_sum, mut filter_sum) =
            (0.0f64, 0.0f64, 0.0f64);
        for c in &stats.claims {
            assert!(matches!(c.arch, Arch::Gpu));
            assert!(c.exec_secs >= 0.0 && c.exec_secs.is_finite());
            assert!(c.transfer_secs >= 0.0 && c.transfer_secs.is_finite());
            assert!(c.filter_secs >= 0.0 && c.filter_secs.is_finite());
            assert!(
                (c.secs - (c.exec_secs + c.transfer_secs + c.filter_secs)).abs()
                    < 1e-9,
                "{mode:?}: pipelined claim secs = exec + transfer + filter \
                 (resource time)"
            );
            exec_sum += c.exec_secs;
            transfer_sum += c.transfer_secs;
            filter_sum += c.filter_secs;
        }
        assert!((stats.exec_time - exec_sum).abs() < 1e-9, "{mode:?}");
        assert!((stats.transfer_time - transfer_sum).abs() < 1e-9, "{mode:?}");
        assert!((stats.filter_time - filter_sum).abs() < 1e-9, "{mode:?}");
        assert!(stats.exec_time > 0.0, "{mode:?}: claims executed device tiles");
        // the copy is real work on every mode: a drain that found pairs
        // must have spent time converting device output into host buffers
        if stats.result_pairs > 0 {
            assert!(
                stats.transfer_time > 0.0,
                "{mode:?}: transfer lane must carry the device-to-host copy"
            );
        }
    }
}
