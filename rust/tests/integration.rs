//! Cross-module integration tests: the full stack (artifacts -> runtime ->
//! epsilon -> grid -> split -> gpu join -> cpu ranks -> hybrid merge)
//! against ground truth, on all four surrogate families.

use hybrid_knn_join::bench::workloads_quick;
use hybrid_knn_join::data::variance::reorder_by_variance;
use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::prop;

fn engine() -> Engine {
    Engine::load_default().expect("run `make artifacts` first")
}

/// Hybrid output must equal the kd-tree oracle on every workload family.
#[test]
fn hybrid_exact_on_all_workload_families() {
    let e = engine();
    for w in workloads_quick() {
        let data = w.dataset();
        let k = w.table_k.min(5);
        let mut p = HybridParams::new(k);
        p.cpu_ranks = 2;
        p.gamma = 0.4;
        p.rho = 0.2;
        let rep = HybridKnnJoin::run(&e, &data, &p).expect(w.name);
        assert_eq!(
            rep.result.solved_count(k),
            data.len(),
            "{}: all queries solved",
            w.name
        );
        let (rdata, _) = reorder_by_variance(&data);
        let tree = KdTree::build(&rdata);
        for q in (0..data.len()).step_by(71) {
            let got = rep.result.get(q);
            let want = tree.knn(&rdata, rdata.point(q), k, q as u32);
            assert_eq!(got.len(), want.len(), "{} q={q}", w.name);
            for (g, r) in got.iter().zip(&want) {
                assert!(
                    (g.dist2 - r.dist2).abs() < 1e-3 * (1.0 + r.dist2),
                    "{} q={q}: {g:?} vs {r:?}",
                    w.name
                );
            }
        }
    }
}

/// The result is invariant to how the work is split: sweeping beta/gamma/
/// rho (including pure-CPU and pure-GPU-leaning splits) changes only the
/// schedule, never the neighbors.
#[test]
fn split_invariance_property() {
    let e = engine();
    let data = susy_like(700).generate(99);
    let k = 3;
    let (rdata, _) = reorder_by_variance(&data);
    let tree = KdTree::build(&rdata);
    let oracle: Vec<Vec<f64>> = (0..data.len())
        .map(|q| {
            tree.knn(&rdata, rdata.point(q), k, q as u32)
                .iter()
                .map(|n| n.dist2)
                .collect()
        })
        .collect();

    prop::cases(6, 0x1B7, |rng| {
        let mut p = HybridParams::new(k);
        p.cpu_ranks = 2;
        p.beta = rng.f64();
        p.gamma = rng.f64();
        p.rho = rng.f64();
        let rep = HybridKnnJoin::run(&e, &data, &p).unwrap();
        for q in (0..data.len()).step_by(59) {
            let got = rep.result.get(q);
            assert_eq!(got.len(), oracle[q].len());
            for (g, w) in got.iter().zip(&oracle[q]) {
                assert!(
                    (g.dist2 - w).abs() < 1e-3 * (1.0 + w),
                    "beta={} gamma={} rho={} q={q}",
                    p.beta,
                    p.gamma,
                    p.rho
                );
            }
        }
    });
}

/// GPU-JOIN (device path) and EXACT-ANN (host path) agree on the queries
/// the GPU solves - the two engines implement the same semantics.
#[test]
fn gpu_and_cpu_engines_agree() {
    let e = engine();
    let data = susy_like(800).generate(100);
    let (data, _) = reorder_by_variance(&data);
    let sel = EpsilonSelector::default().select(&e, &data, 4, 0.2).unwrap();
    let grid = GridIndex::build(&data, 6, sel.eps);
    let queries: Vec<u32> = (0..data.len() as u32).collect();
    let params = GpuJoinParams::new(4, sel.eps);
    let gout = gpu_join(&e, &data, &grid, &queries, &params).unwrap();
    let tree = KdTree::build(&data);
    let cout = exact_ann(&data, &tree, &queries, 4, 2);
    let mut compared = 0;
    for q in 0..data.len() {
        let g = gout.result.get(q);
        if g.len() < 4 {
            continue; // failed on GPU; CPU handles it in the hybrid
        }
        let c = cout.result.get(q);
        for (a, b) in g.iter().zip(c) {
            assert!((a.dist2 - b.dist2).abs() < 1e-3 * (1.0 + b.dist2), "q={q}");
        }
        compared += 1;
    }
    assert!(compared > 0, "GPU solved nothing at eps={}", sel.eps);
}

/// REFIMPL equals brute-force collection through the device path.
#[test]
fn refimpl_vs_device_brute() {
    let e = engine();
    let data = chist_like(400).generate(101);
    let k = 4;
    let tree = KdTree::build(&data);
    let r = ref_impl(&data, &tree, k, 2);
    let queries: Vec<u32> = (0..data.len() as u32).collect();
    let b = brute_join_linear(&e, &data, &queries, 1.0, Some(k)).unwrap();
    let bres = b.result.unwrap();
    for q in (0..data.len()).step_by(29) {
        for (x, y) in r.result.get(q).iter().zip(bres.get(q)) {
            assert!((x.dist2 - y.dist2).abs() < 1e-3 * (1.0 + y.dist2), "q={q}");
        }
    }
}

/// K larger than any cell population: everything fails on the GPU and the
/// CPU still completes the join exactly.
#[test]
fn failure_flood_reassignment() {
    let e = engine();
    let data = songs_like(400).generate(102);
    let mut p = HybridParams::new(16);
    p.cpu_ranks = 2;
    // tiny eps via beta=0 on a high-dim set -> most GPU queries fail
    let rep = HybridKnnJoin::run(&e, &data, &p).unwrap();
    assert_eq!(rep.result.solved_count(16), data.len());
    // accounting stays consistent even under mass failure
    assert_eq!(rep.solved_on_gpu + rep.q_fail, rep.q_gpu);
}

/// Dataset IO round-trips feed the pipeline unchanged.
#[test]
fn io_roundtrip_through_hybrid() {
    let e = engine();
    let data = susy_like(300).generate(103);
    let path = std::env::temp_dir().join(format!("hknn_it_{}.bin", std::process::id()));
    hybrid_knn_join::data::io::write_bin(&data, &path).unwrap();
    let loaded = hybrid_knn_join::data::io::read_bin(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.raw(), data.raw());
    let mut p = HybridParams::new(3);
    p.cpu_ranks = 2;
    let a = HybridKnnJoin::run(&e, &data, &p).unwrap();
    let b = HybridKnnJoin::run(&e, &loaded, &p).unwrap();
    for q in (0..data.len()).step_by(37) {
        let (x, y) = (a.result.get(q), b.result.get(q));
        assert_eq!(x.len(), y.len());
        for (m, n) in x.iter().zip(y) {
            assert_eq!(m.id, n.id);
        }
    }
}
