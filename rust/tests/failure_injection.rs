//! Failure injection: the runtime must fail loudly and cleanly on broken
//! artifact trees, the engines must behave on degenerate inputs, the
//! queue's Q^Fail recirculation contract must survive the pipelined
//! master's interleaving (claim i's failures published only after claim
//! i+1 was taken), and the fault subsystem's claim-scoped recovery must
//! keep the join's answer and its exactly-once accounting intact under
//! injected exec/transfer/filter/stall faults (DESIGN.md §9).

use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::sched::{first_batch_work, next_batch_work};
use hybrid_knn_join::util::prop;

fn tmp_dir(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("hknn_fi_{}_{name}", std::process::id()));
    fs::create_dir_all(&p).unwrap();
    p
}

/// CI's chaos matrix pins the GPU drain's pipeline depth via
/// `HKNN_FAULT_DEPTH` (1 = sync, 2 = two-stage, 3 = three-stage) so the
/// recovery paths run under every drain's interleaving; unset, the fault
/// tests pick their own drains.
fn pinned_drain() -> Option<DrainMode> {
    match std::env::var("HKNN_FAULT_DEPTH").ok().as_deref() {
        Some("1") => Some(DrainMode::Sync),
        Some("2") => Some(DrainMode::TwoStage),
        Some("3") => Some(DrainMode::ThreeStage),
        _ => None,
    }
}

#[test]
fn missing_manifest_is_clean_error() {
    let dir = tmp_dir("missing");
    let err = match Engine::load(&dir) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("load must fail"),
    };
    assert!(err.contains("manifest"), "unhelpful error: {err}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn malformed_manifest_is_clean_error() {
    let dir = tmp_dir("malformed");
    fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(Engine::load(&dir).is_err());
    fs::write(dir.join("manifest.json"), r#"{"format":"other","artifacts":[]}"#)
        .unwrap();
    let err = match Engine::load(&dir) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("load must fail"),
    };
    assert!(err.contains("format"), "{err}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn corrupt_hlo_text_fails_at_compile_not_at_load() {
    let dir = tmp_dir("corrupt");
    fs::write(
        dir.join("manifest.json"),
        r#"{"format":"hlo-text","artifacts":[
            {"name":"dist_q32_c256_d24","file":"bad.hlo.txt","kind":"dist",
             "params":{"qt":32,"ct":256,"d":24},"out_shapes":[[32,256]]}]}"#,
    )
    .unwrap();
    fs::write(dir.join("bad.hlo.txt"), "HloModule garbage\nnot an hlo body").unwrap();
    // manifest loads fine (lazy compilation)...
    let engine = Engine::load(&dir).unwrap();
    // ...execution of the corrupt artifact errors instead of aborting
    let q = vec![0f32; 32 * 24];
    let c = vec![0f32; 256 * 24];
    let args: [(&[f32], &[i64]); 2] = [(&q, &[32, 24]), (&c, &[256, 24])];
    assert!(engine.exec("dist_q32_c256_d24", &args).is_err());
    fs::remove_dir_all(dir).ok();
}

#[test]
fn missing_artifact_file_is_clean_error() {
    let dir = tmp_dir("missingfile");
    fs::write(
        dir.join("manifest.json"),
        r#"{"format":"hlo-text","artifacts":[
            {"name":"dist_q32_c256_d24","file":"nope.hlo.txt","kind":"dist",
             "params":{"qt":32,"ct":256,"d":24},"out_shapes":[[32,256]]}]}"#,
    )
    .unwrap();
    let engine = Engine::load(&dir).unwrap();
    let q = vec![0f32; 32 * 24];
    let c = vec![0f32; 256 * 24];
    let args: [(&[f32], &[i64]); 2] = [(&q, &[32, 24]), (&c, &[256, 24])];
    assert!(engine.exec("dist_q32_c256_d24", &args).is_err());
    fs::remove_dir_all(dir).ok();
}

#[test]
fn dims_beyond_artifacts_is_clean_error() {
    // 600 dims > the largest artifact (520): hybrid must error, not panic
    let engine = Engine::load_default().unwrap();
    let data = Dataset::new(vec![0.5f32; 40 * 600], 600);
    let mut p = HybridParams::new(2);
    p.cpu_ranks = 1;
    assert!(HybridKnnJoin::run(&engine, &data, &p).is_err());
}

#[test]
fn degenerate_datasets_do_not_crash() {
    let engine = Engine::load_default().unwrap();
    // all-identical points: every distance zero
    let data = Dataset::new(vec![1.0f32; 128 * 8], 8);
    let mut p = HybridParams::new(3);
    p.cpu_ranks = 2;
    let rep = HybridKnnJoin::run(&engine, &data, &p).unwrap();
    assert_eq!(rep.result.solved_count(3), data.len());
    for n in rep.result.get(0) {
        assert_eq!(n.dist2, 0.0);
    }

    // K >= |D|: every query can only find |D|-1 neighbors
    let small = susy_like(20).generate(1);
    let mut p = HybridParams::new(64);
    p.cpu_ranks = 1;
    let rep = HybridKnnJoin::run(&engine, &small, &p).unwrap();
    for q in 0..small.len() {
        assert_eq!(rep.result.get(q).len(), small.len() - 1);
    }
}

#[test]
fn deferred_recirculation_never_loses_or_duplicates_queries() {
    // The pipelined GPU master resolves claim i only after later claims
    // were already taken off the head - one claim behind under the
    // two-stage drain, up to three behind under the three-stage drain
    // (exec i+1 / transfer i / filter i-1 in flight at once) - so claim
    // i's Q^Fail enters the recirculation buffer *behind* its
    // successors. Inject failures under exactly that interleaving at a
    // random pipeline depth, with CPU ranks racing the tail and the
    // recirc buffer, and assert the exactly-once contract holds: no
    // query lost, none double-written, none resolved twice across the
    // CPU ranks and the GPU master.
    prop::cases(8, 0xFA11, |rng| {
        let n = 400 + rng.below(1200);
        let d = susy_like(n).generate(rng.next_u64());
        let grid = GridIndex::build(&d, 6, 1.5 + rng.f64() * 2.0);
        let queries: Vec<u32> = (0..d.len() as u32).collect();
        let gamma = rng.f64();
        let rho = rng.f64() * 0.4;
        let queue = build_queue(&d, &grid, &queries, 4, gamma, rho, true);
        let ranks = 1 + rng.below(3);
        let chunk = 8 + rng.below(24);
        let fail_mod = 2 + rng.below(5); // fail every fail_mod-th query
        let depth = 1 + rng.below(3); // resolve lag: sync+1 .. three-stage
        let solved: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let reserve = queue.reserve();
        let mut total_failed = 0usize;

        std::thread::scope(|scope| {
            // pipelined master pattern: a `depth`-claim delay between
            // failing a query and publishing it for recirculation
            {
                let (queue, solved) = (&queue, &solved);
                let total_failed = &mut total_failed;
                scope.spawn(move || {
                    let mut deferred: std::collections::VecDeque<Vec<u32>> =
                        std::collections::VecDeque::new();
                    let mut target = first_batch_work(
                        queue.head_work_remaining(queue.len()),
                        queue.dense_work(),
                    );
                    while let Some(r) = queue.claim_head_work(target, queue.len()) {
                        // a new claim is taken: the claim `depth` back
                        // resolves NOW and its failures land
                        while deferred.len() >= depth {
                            let f = deferred.pop_front().unwrap();
                            *total_failed += f.len();
                            queue.push_failed(&f);
                        }
                        let mut failed = Vec::new();
                        for (i, &q) in
                            queue.query_slice(r.clone()).iter().enumerate()
                        {
                            if i % fail_mod == fail_mod - 1 {
                                failed.push(q);
                            } else {
                                solved[q as usize].fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        deferred.push_back(failed);
                        target = next_batch_work(
                            queue.head_work_remaining(queue.len()),
                            1.0,
                            queue.cpu_work_rate(),
                        );
                    }
                    // in-flight claims' failures: published after the head
                    // is exhausted, right before gpu_done - the drains'
                    // resolve-at-end path, oldest claim first
                    for f in deferred {
                        *total_failed += f.len();
                        queue.push_failed(&f);
                    }
                    queue.set_gpu_done();
                });
            }
            // CPU ranks: tail chunks, then recirculated failures, exit
            // only after done + two empty claim attempts
            for _ in 0..ranks {
                let (queue, solved) = (&queue, &solved);
                scope.spawn(move || loop {
                    let done = queue.gpu_done();
                    if let Some(r) = queue.claim_tail(chunk) {
                        for &q in queue.query_slice(r) {
                            solved[q as usize].fetch_add(1, Ordering::Relaxed);
                        }
                        continue;
                    }
                    if let Some(ids) = queue.claim_recirc(chunk) {
                        for q in ids {
                            solved[q as usize].fetch_add(1, Ordering::Relaxed);
                        }
                        continue;
                    }
                    if done {
                        break;
                    }
                    std::thread::yield_now();
                });
            }
        });

        // exactly-once across master + ranks: failures were re-solved by
        // exactly one CPU claimant, everything else by its first owner
        for (q, s) in solved.iter().enumerate() {
            assert_eq!(
                s.load(Ordering::Relaxed),
                1,
                "query {q} resolved {} times (n={n} γ={gamma:.2} ρ={rho:.2} \
                 fail_mod={fail_mod})",
                s.load(Ordering::Relaxed)
            );
        }
        assert_eq!(queue.claimed_head() + queue.claimed_tail(), n);
        assert_eq!(
            queue.recirc_pushed(),
            total_failed,
            "every deferred failure was published"
        );
        assert!(queue.claimed_tail() >= reserve, "ρ reserve stays CPU-owned");
    });
}

#[test]
fn persistent_gpu_fault_claim0_completes_cpu_only_bit_identical() {
    // The acceptance scenario: a device that errors on every attempt of
    // every claim. The master reclaims claim 0, demotes itself, and the
    // CPU ranks absorb the abandoned head plus the recirculated queries -
    // the run completes, reports the faults, and the KNN table is
    // BIT-identical to a forced-CPU-only run (degradation changes who
    // computes, never what: both paths end in the same kd-tree search).
    let engine = Engine::load_default().unwrap();
    let data = susy_like(600).generate(0xDE6);
    let mut base = HybridParams::new(4);
    base.cpu_ranks = 2;
    if let Some(d) = pinned_drain() {
        base.gpu_drain = d;
    }

    // the reference: ρ = 1.0 schedules the pure-CPU run up front
    let mut p_cpu = base.clone();
    p_cpu.rho = 1.0;
    let want = HybridKnnJoin::run(&engine, &data, &p_cpu).unwrap();

    let mut p = base.clone();
    p.fault = FaultPlan::one(FaultSpec::persistent(FaultKind::ExecError, 0));
    p.recovery.retry_limit = 0; // a dead device earns no retries
    p.recovery.demote_after = 1; // demote on the first reclaim
    p.recovery.backoff_base_secs = 0.0;
    let rep = HybridKnnJoin::run(&engine, &data, &p).unwrap();

    assert!(rep.degraded, "persistent fault must demote the GPU master");
    assert_eq!(rep.solved_on_gpu, 0, "a dead device solves nothing");
    assert!(rep.gpu_faults >= 1, "the fault must be visible in the report");
    assert_eq!(rep.gpu_retries, 0);
    assert!(rep.reclaimed_cells >= 1, "the failed claim's cells recirculated");
    assert_eq!(rep.fault_log.count(FaultAction::Demoted), 1);
    assert!(rep.fault_log.count(FaultAction::Reclaimed) >= 1);
    assert!(
        rep.fault_log.events.iter().all(|e| e.kind == FaultKind::ExecError),
        "only the injected kind may appear: {:?}",
        rep.fault_log.events
    );
    assert_eq!(rep.q_fail + rep.solved_on_gpu, rep.q_gpu, "accounting closed");
    assert_eq!(rep.result.solved_count(4), data.len());
    for q in 0..data.len() {
        let (a, b) = (rep.result.get(q), want.result.get(q));
        assert_eq!(a.len(), b.len(), "q={q}");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.id, y.id, "q={q}");
            assert_eq!(
                x.dist2.to_bits(),
                y.dist2.to_bits(),
                "q={q}: degraded run must be bit-identical to CPU-only"
            );
        }
    }
}

#[test]
fn transient_faults_are_retried_in_place() {
    // One transient fault per stage kind: the master retries the claim
    // synchronously (spec disarmed, retry clean), nothing recirculates,
    // no demotion, and the output matches the fault-free run.
    let engine = Engine::load_default().unwrap();
    let data = susy_like(500).generate(0x7E57);
    let mut base = HybridParams::new(3);
    base.cpu_ranks = 2;
    if let Some(d) = pinned_drain() {
        base.gpu_drain = d;
    }
    let want = HybridKnnJoin::run(&engine, &data, &base).unwrap();

    for kind in [
        FaultKind::ExecError,
        FaultKind::TransferError,
        FaultKind::FilterPanic,
    ] {
        let mut p = base.clone();
        p.fault = FaultPlan::one(FaultSpec::transient(kind, 0, 0));
        p.recovery.backoff_base_secs = 0.0; // no point sleeping in tests
        let rep = HybridKnnJoin::run(&engine, &data, &p).unwrap();
        assert!(!rep.degraded, "{kind}: one transient must not demote");
        assert_eq!(rep.gpu_retries, 1, "{kind}: exactly one retry");
        assert_eq!(rep.gpu_faults, 1, "{kind}");
        assert_eq!(rep.fault_log.count(FaultAction::Retried), 1, "{kind}");
        assert_eq!(rep.fault_log.count(FaultAction::Reclaimed), 0, "{kind}");
        assert_eq!(rep.reclaimed_cells, 0, "{kind}");
        assert_eq!(rep.result.solved_count(3), data.len(), "{kind}");
        for q in (0..data.len()).step_by(17) {
            let (a, b) = (rep.result.get(q), want.result.get(q));
            assert_eq!(a.len(), b.len(), "{kind} q={q}");
            for (x, y) in a.iter().zip(b) {
                assert!(
                    (x.dist2 - y.dist2).abs() < 1e-4 * (1.0 + y.dist2),
                    "{kind} q={q}: retried run diverged"
                );
            }
        }
    }
}

#[test]
fn randomized_fault_plans_preserve_results_and_accounting() {
    // The recovery property: under ANY seeded mix of transient faults
    // (kinds x claims x rounds, all three drain modes) the join completes
    // with the fault-free answer and the exactly-once accounting intact -
    // solved and recirculated queries partition the claims, nothing lost,
    // nothing double-counted.
    let engine = Engine::load_default().unwrap();
    let drains = [DrainMode::Sync, DrainMode::TwoStage, DrainMode::ThreeStage];
    prop::cases(6, 0xFA17, |rng| {
        let n = 300 + rng.below(400);
        let data = susy_like(n).generate(rng.next_u64());
        let mut base = HybridParams::new(3);
        base.cpu_ranks = 1 + rng.below(2);
        base.gamma = rng.f64() * 0.5;
        base.rho = rng.f64() * 0.3;
        base.gpu_drain = pinned_drain().unwrap_or(drains[rng.below(3)]);
        let want = HybridKnnJoin::run(&engine, &data, &base).unwrap();

        let mut p = base.clone();
        p.fault = FaultPlan::random(rng);
        p.recovery.backoff_base_secs = 0.0;
        let rep = HybridKnnJoin::run(&engine, &data, &p).unwrap();

        // exactly-once accounting under injected faults
        assert_eq!(rep.q_gpu + rep.q_cpu, n, "head/tail partition");
        assert_eq!(rep.solved_on_gpu + rep.q_fail, rep.q_gpu, "gpu side closed");
        assert_eq!(rep.result.solved_count(3), n, "every query solved");
        let claimed: usize = rep.claims.iter().map(|c| c.queries).sum();
        assert_eq!(claimed, n + rep.q_fail, "claims + recirculated");
        assert_eq!(
            rep.gpu_faults,
            rep.fault_log.count(FaultAction::Retried)
                + rep.fault_log.count(FaultAction::Reclaimed),
            "fault counter mirrors the log"
        );
        // results match the fault-free run
        for q in (0..n).step_by(7) {
            let (a, b) = (rep.result.get(q), want.result.get(q));
            assert_eq!(a.len(), b.len(), "q={q}");
            for (x, y) in a.iter().zip(b) {
                assert!(
                    (x.dist2 - y.dist2).abs() < 1e-4 * (1.0 + y.dist2),
                    "q={q}: faulted run diverged (drain {:?})",
                    base.gpu_drain
                );
            }
        }
    });
}

#[test]
fn stalled_exec_trips_watchdog_and_degrades() {
    // A device that hangs mid-claim: the exec hook sleeps 0.5 s per
    // round from claim 1 on. With the deadline floored at 0.2 s (and
    // slack zeroed so the floor IS the deadline once rate evidence
    // exists), the round-boundary watchdog trips, the claim reclaims
    // (retry budget 0), and one reclaim demotes the master. Claim 0 is
    // deliberately clean: the first claim has no rate evidence and so -
    // by design - can never time out.
    let engine = Engine::load_default().unwrap();
    let data = susy_like(700).generate(0x57A1);
    let mut p = HybridParams::new(3);
    p.cpu_ranks = 1;
    if let Some(d) = pinned_drain() {
        p.gpu_drain = d;
    }
    let mut spec = FaultSpec::persistent(FaultKind::StallTimeout, 1);
    spec.stall_secs = 0.5;
    p.fault = FaultPlan::one(spec);
    p.recovery.retry_limit = 0;
    p.recovery.demote_after = 1;
    p.recovery.backoff_base_secs = 0.0;
    p.recovery.watchdog_slack = 0.0;
    p.recovery.watchdog_min_secs = 0.2;
    let rep = HybridKnnJoin::run(&engine, &data, &p).unwrap();

    assert!(rep.degraded, "a stalled device must demote the master");
    assert!(
        rep.fault_log
            .events
            .iter()
            .any(|e| e.kind == FaultKind::StallTimeout),
        "the watchdog trip must be logged as a stall: {:?}",
        rep.fault_log.events
    );
    assert_eq!(rep.fault_log.count(FaultAction::Demoted), 1);
    assert_eq!(rep.result.solved_count(3), data.len(), "run still completes");
    assert_eq!(rep.q_gpu + rep.q_cpu, data.len());
    assert_eq!(rep.solved_on_gpu + rep.q_fail, rep.q_gpu);
}

#[test]
fn estimator_on_tiny_gpu_sets() {
    // a query set that maps to a single cell must still batch correctly
    let engine = Engine::load_default().unwrap();
    let data = susy_like(300).generate(7);
    let sel = EpsilonSelector::default().select(&engine, &data, 2, 1.0).unwrap();
    let grid = GridIndex::build(&data, 6, sel.eps.max(1e3)); // giant cells
    let queries: Vec<u32> = (0..data.len() as u32).collect();
    let params = GpuJoinParams::new(2, sel.eps.max(1e3));
    let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
    assert_eq!(out.solved + out.failed.len(), queries.len());
}
