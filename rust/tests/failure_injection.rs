//! Failure injection: the runtime must fail loudly and cleanly on broken
//! artifact trees, and the engines must behave on degenerate inputs.

use std::fs;
use std::path::PathBuf;

use hybrid_knn_join::prelude::*;

fn tmp_dir(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("hknn_fi_{}_{name}", std::process::id()));
    fs::create_dir_all(&p).unwrap();
    p
}

#[test]
fn missing_manifest_is_clean_error() {
    let dir = tmp_dir("missing");
    let err = match Engine::load(&dir) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("load must fail"),
    };
    assert!(err.contains("manifest"), "unhelpful error: {err}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn malformed_manifest_is_clean_error() {
    let dir = tmp_dir("malformed");
    fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(Engine::load(&dir).is_err());
    fs::write(dir.join("manifest.json"), r#"{"format":"other","artifacts":[]}"#)
        .unwrap();
    let err = match Engine::load(&dir) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("load must fail"),
    };
    assert!(err.contains("format"), "{err}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn corrupt_hlo_text_fails_at_compile_not_at_load() {
    let dir = tmp_dir("corrupt");
    fs::write(
        dir.join("manifest.json"),
        r#"{"format":"hlo-text","artifacts":[
            {"name":"dist_q32_c256_d24","file":"bad.hlo.txt","kind":"dist",
             "params":{"qt":32,"ct":256,"d":24},"out_shapes":[[32,256]]}]}"#,
    )
    .unwrap();
    fs::write(dir.join("bad.hlo.txt"), "HloModule garbage\nnot an hlo body").unwrap();
    // manifest loads fine (lazy compilation)...
    let engine = Engine::load(&dir).unwrap();
    // ...execution of the corrupt artifact errors instead of aborting
    let q = vec![0f32; 32 * 24];
    let c = vec![0f32; 256 * 24];
    let args: [(&[f32], &[i64]); 2] = [(&q, &[32, 24]), (&c, &[256, 24])];
    assert!(engine.exec("dist_q32_c256_d24", &args).is_err());
    fs::remove_dir_all(dir).ok();
}

#[test]
fn missing_artifact_file_is_clean_error() {
    let dir = tmp_dir("missingfile");
    fs::write(
        dir.join("manifest.json"),
        r#"{"format":"hlo-text","artifacts":[
            {"name":"dist_q32_c256_d24","file":"nope.hlo.txt","kind":"dist",
             "params":{"qt":32,"ct":256,"d":24},"out_shapes":[[32,256]]}]}"#,
    )
    .unwrap();
    let engine = Engine::load(&dir).unwrap();
    let q = vec![0f32; 32 * 24];
    let c = vec![0f32; 256 * 24];
    let args: [(&[f32], &[i64]); 2] = [(&q, &[32, 24]), (&c, &[256, 24])];
    assert!(engine.exec("dist_q32_c256_d24", &args).is_err());
    fs::remove_dir_all(dir).ok();
}

#[test]
fn dims_beyond_artifacts_is_clean_error() {
    // 600 dims > the largest artifact (520): hybrid must error, not panic
    let engine = Engine::load_default().unwrap();
    let data = Dataset::new(vec![0.5f32; 40 * 600], 600);
    let mut p = HybridParams::new(2);
    p.cpu_ranks = 1;
    assert!(HybridKnnJoin::run(&engine, &data, &p).is_err());
}

#[test]
fn degenerate_datasets_do_not_crash() {
    let engine = Engine::load_default().unwrap();
    // all-identical points: every distance zero
    let data = Dataset::new(vec![1.0f32; 128 * 8], 8);
    let mut p = HybridParams::new(3);
    p.cpu_ranks = 2;
    let rep = HybridKnnJoin::run(&engine, &data, &p).unwrap();
    assert_eq!(rep.result.solved_count(3), data.len());
    for n in rep.result.get(0) {
        assert_eq!(n.dist2, 0.0);
    }

    // K >= |D|: every query can only find |D|-1 neighbors
    let small = susy_like(20).generate(1);
    let mut p = HybridParams::new(64);
    p.cpu_ranks = 1;
    let rep = HybridKnnJoin::run(&engine, &small, &p).unwrap();
    for q in 0..small.len() {
        assert_eq!(rep.result.get(q).len(), small.len() - 1);
    }
}

#[test]
fn estimator_on_tiny_gpu_sets() {
    // a query set that maps to a single cell must still batch correctly
    let engine = Engine::load_default().unwrap();
    let data = susy_like(300).generate(7);
    let sel = EpsilonSelector::default().select(&engine, &data, 2, 1.0).unwrap();
    let grid = GridIndex::build(&data, 6, sel.eps.max(1e3)); // giant cells
    let queries: Vec<u32> = (0..data.len() as u32).collect();
    let params = GpuJoinParams::new(2, sel.eps.max(1e3));
    let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
    assert_eq!(out.solved + out.failed.len(), queries.len());
}
