//! Churn suite (DESIGN.md §12): incremental index maintenance locked
//! down by rebuild equivalence.
//!
//! The load-bearing property: a resident engine whose indexes were
//! *patched* through an arbitrary seeded interleaving of inserts,
//! removes, and query flushes is **bit-identical** - same `KnnResult`
//! id/dist² lanes, same solved/failed claim partition, same
//! exactly-once accounting - to an engine *rebuilt from scratch* over
//! the same live set, at every flush boundary, across all three
//! `DrainMode`s and both backend tiers, with fault injection layered on
//! top. Patching (CSR row splices on the grid, the buffered delta set
//! on the kd-tree, epoch-invalidated brute tiles) is an amortisation
//! strategy, never an approximation.
//!
//! Also here, host-side: the CSR patch *locality* contract - a single
//! insert/remove dirties only the mutated cell's own 3^m neighbor row,
//! every other row stays byte-identical - and the kd-tree delta-buffer
//! boundary cases from the Bigger Buffer k-d Trees treatment
//! (arXiv:1512.02831): deleting a not-yet-merged buffered insert,
//! duplicate coordinates split across tree and buffer, and a merge
//! landing mid-query-batch.

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::prop;
use hybrid_knn_join::util::rng::Rng;

/// CI's chaos matrix pins the drain depth via `HKNN_FAULT_DEPTH`
/// (1 = sync, 2 = two-stage, 3 = three-stage); unset, the engine-backed
/// harness sweeps all three itself.
fn drain_modes() -> Vec<DrainMode> {
    match std::env::var("HKNN_FAULT_DEPTH").ok().as_deref() {
        Some("1") => vec![DrainMode::Sync],
        Some("2") => vec![DrainMode::TwoStage],
        Some("3") => vec![DrainMode::ThreeStage],
        _ => vec![DrainMode::Sync, DrainMode::TwoStage, DrainMode::ThreeStage],
    }
}

// ---------------------------------------------------------------------
// CSR patch locality (host-side, no engine)
// ---------------------------------------------------------------------

/// Per-cell snapshot keyed by cell id (stable across rank shifts):
/// member list, neighbor row as cell ids, memoized adjacent population.
type CellSnap = (u64, Vec<u32>, Vec<u64>, usize);

fn snapshot(g: &GridIndex) -> Vec<CellSnap> {
    (0..g.non_empty_cells())
        .map(|r| {
            let row: Vec<u64> = g
                .adjacent_ranks(r)
                .iter()
                .map(|&a| g.rank_cell_id(a as usize))
                .collect();
            (
                g.rank_cell_id(r),
                g.rank_points(r).to_vec(),
                row,
                g.adjacent_population_of_rank(r),
            )
        })
        .collect()
}

/// Assert every cell outside `dirty` (a set of cell ids) is
/// byte-identical between the two snapshots.
fn assert_local(before: &[CellSnap], after: &[CellSnap], dirty: &[u64]) {
    let find = |snaps: &[CellSnap], cid: u64| -> Option<CellSnap> {
        snaps.iter().find(|s| s.0 == cid).cloned()
    };
    for s in before {
        if dirty.contains(&s.0) {
            continue;
        }
        let a = find(after, s.0)
            .unwrap_or_else(|| panic!("cell {} vanished outside dirty set", s.0));
        assert_eq!(s.1, a.1, "cell {}: member list changed", s.0);
        assert_eq!(s.2, a.2, "cell {}: neighbor row changed", s.0);
        assert_eq!(s.3, a.3, "cell {}: adjacent population changed", s.0);
    }
    for a in after {
        assert!(
            dirty.contains(&a.0) || find(before, a.0).is_some(),
            "cell {} born outside dirty set",
            a.0
        );
    }
}

#[test]
fn csr_patch_dirties_only_the_mutated_neighborhood() {
    // ISSUE 9 satellite: byte-equality of every CSR row outside the
    // dirtied 3^m neighborhood after each single insert / remove -
    // including cell-birth and cell-death mutations (compared keyed by
    // cell id, so rank renumbering does not mask a violation).
    prop::cases(10, 0xC10C, |rng| {
        let mut d = susy_like(240 + rng.below(160)).generate(rng.next_u64());
        let m = 2 + rng.below(3);
        let mut g = GridIndex::build(&d, m, 0.8 + rng.f64() * 1.8);
        let mut live: Vec<u32> = (0..d.len() as u32).collect();
        for _ in 0..30 {
            let before = snapshot(&g);
            let insert = rng.f64() < 0.6 || live.is_empty();
            let touched_cell = if insert {
                let src = rng.below(d.len());
                let mut p = d.point(src).to_vec();
                // jitter: sometimes same cell, sometimes a fresh one
                for x in p.iter_mut().take(m) {
                    *x += (rng.f64() as f32 - 0.5) * 4.0;
                }
                let id = d.push_row(&p);
                g.insert(&d, id);
                live.push(id);
                g.cell_id_of_id(id)
            } else {
                let slot = rng.below(live.len());
                let id = live.swap_remove(slot);
                let cid = g.cell_id_of_id(id);
                assert!(g.remove(id), "live id {id} must be indexed");
                cid
            };
            // the dirty set is the touched cell's neighbor row - taken
            // from whichever side of the mutation the cell exists on
            let row_of = |g: &GridIndex, snaps: &[CellSnap]| -> Vec<u64> {
                match g.rank_of_cell_id(touched_cell) {
                    Some(r) => g
                        .adjacent_ranks(r)
                        .iter()
                        .map(|&a| g.rank_cell_id(a as usize))
                        .collect(),
                    None => snaps
                        .iter()
                        .find(|s| s.0 == touched_cell)
                        .map(|s| s.2.clone())
                        .unwrap_or_default(),
                }
            };
            let mut dirty = row_of(&g, &before);
            dirty.push(touched_cell);
            assert_local(&before, &snapshot(&g), &dirty);
        }
        // belt and braces: the patched grid is still in canonical form
        g.assert_same_layout(&g.rebuilt(&d));
    });
}

#[test]
fn csr_duplicate_insert_then_remove_roundtrips_byte_identically() {
    // the no-birth / no-death pair: ranks are stable, so the roundtrip
    // must restore every array byte-for-byte
    let mut d = susy_like(300).generate(0xA7);
    let mut g = GridIndex::build(&d, 4, 1.5);
    let before = snapshot(&g);
    let epoch0 = g.epoch();
    let id = d.push_row(&d.point(7).to_vec()); // duplicate: same cell as 7
    g.insert(&d, id);
    assert_eq!(g.cell_id_of_id(id), g.cell_id_of_id(7));
    let rc = g.cell_rank_of(id);
    let dirty: Vec<u64> = g
        .adjacent_ranks(rc)
        .iter()
        .map(|&a| g.rank_cell_id(a as usize))
        .collect();
    let mid = snapshot(&g);
    assert_local(&before, &mid, &dirty);
    // inside the dirty row, exactly the memoized populations move
    for s in &before {
        if !dirty.contains(&s.0) {
            continue;
        }
        let a = mid.iter().find(|x| x.0 == s.0).unwrap();
        assert_eq!(a.3, s.3 + 1, "cell {}: adj_pop bumps by one", s.0);
        assert_eq!(a.2, s.2, "cell {}: neighbor row untouched", s.0);
    }
    assert!(g.remove(id));
    assert_eq!(snapshot(&g), before, "roundtrip restores every row");
    assert_eq!(g.epoch(), epoch0 + 2, "two mutations, two epochs");
    g.assert_same_layout(&g.rebuilt(&d));
}

// ---------------------------------------------------------------------
// kd-tree delta-buffer boundary cases (host-side, no engine)
// ---------------------------------------------------------------------

fn assert_knn_bit_equal(a: &[Neighbor], b: &[Neighbor], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: neighbor count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{tag}: id lane");
        assert_eq!(
            x.dist2.to_bits(),
            y.dist2.to_bits(),
            "{tag}: dist2 lane ({} vs {})",
            x.dist2,
            y.dist2
        );
    }
}

#[test]
fn kdtree_delete_of_unmerged_buffered_insert_is_a_true_noop() {
    let mut d = susy_like(250).generate(0xB1);
    let extra = susy_like(6).generate(0xB2);
    let mut t = KdTree::build(&d);
    t.set_merge_limit(usize::MAX / 2); // keep the delta buffered
    let pristine = KdTree::build_from_ids(&d, (0..250).collect());
    let mut ids = Vec::new();
    for i in 0..extra.len() {
        let id = d.push_row(extra.point(i));
        t.insert(&d, id);
        ids.push(id);
    }
    assert_eq!(t.deferred(), extra.len());
    for &id in &ids {
        assert!(t.remove(id), "buffered insert {id} is live");
    }
    assert_eq!(t.len(), 250, "live count back to the original corpus");
    for q in (0..250).step_by(23) {
        let got = t.knn(&d, d.point(q), 5, u32::MAX);
        let want = pristine.knn(&d, d.point(q), 5, u32::MAX);
        assert_knn_bit_equal(&got, &want, &format!("q={q} vs pristine"));
        let reb = t.rebuilt(&d).knn(&d, d.point(q), 5, u32::MAX);
        assert_knn_bit_equal(&got, &reb, &format!("q={q} vs rebuilt"));
    }
}

#[test]
fn kdtree_duplicate_coordinates_across_tree_and_buffer_are_canonical() {
    // the canonical k-set contract: ties on dist2 resolve by id, so a
    // duplicate living in the buffer while its twin lives in the tree
    // must produce the same k-set as a rebuilt tree holding both
    let mut d = susy_like(220).generate(0xB3);
    let mut t = KdTree::build(&d);
    t.set_merge_limit(usize::MAX / 2);
    for src in [5usize, 77, 140] {
        let id = d.push_row(&d.point(src).to_vec());
        t.insert(&d, id);
    }
    let oracle = t.rebuilt(&d);
    for src in [5usize, 77, 140, 0, 33] {
        for k in [1usize, 3, 8] {
            let got = t.knn(&d, d.point(src), k, u32::MAX);
            let want = oracle.knn(&d, d.point(src), k, u32::MAX);
            assert_knn_bit_equal(&got, &want, &format!("src={src} k={k}"));
            if src == 5 || src == 77 || src == 140 {
                assert_eq!(
                    got[0].dist2.to_bits(),
                    0f64.to_bits(),
                    "src={src}: a zero-distance twin exists"
                );
            }
        }
    }
}

#[test]
fn kdtree_merge_mid_query_batch_is_invisible() {
    let mut d = susy_like(260).generate(0xB4);
    let extra = susy_like(40).generate(0xB5);
    let mut t = KdTree::build(&d);
    t.set_merge_limit(usize::MAX / 2);
    let mut rng = Rng::new(0xB6);
    for i in 0..extra.len() {
        let id = d.push_row(extra.point(i));
        t.insert(&d, id);
        if rng.f64() < 0.25 {
            assert!(t.remove(id));
        }
    }
    for slot in rng.sample_indices(260, 12) {
        assert!(t.remove(slot as u32), "tree-resident removal");
    }
    let oracle = t.rebuilt(&d);
    let queries: Vec<usize> = (0..60).map(|i| (i * 7) % d.len()).collect();
    for (i, &q) in queries.iter().enumerate() {
        if i == queries.len() / 2 {
            t.merge(&d); // fold the delta mid-batch
            assert_eq!(t.deferred(), 0);
        }
        let got = t.knn(&d, d.point(q), 6, u32::MAX);
        let want = oracle.knn(&d, d.point(q), 6, u32::MAX);
        assert_knn_bit_equal(&got, &want, &format!("i={i} q={q}"));
    }
}

// ---------------------------------------------------------------------
// Engine-backed rebuild-equivalence harness (the headline property)
// ---------------------------------------------------------------------

/// Drive one seeded insert/remove/query interleaving through a resident
/// [`KnnEngine`] and, at every flush boundary, compare bit-exactly
/// against a from-scratch rebuild over the same live set.
fn churn_harness(
    engine: &Engine,
    mode: DrainMode,
    backend: BackendMode,
    seed: u64,
    fault: bool,
) -> usize {
    let corpus = susy_like(420).generate(seed);
    let extra = susy_like(160).generate(seed ^ 0x5EED);
    let queries = susy_like(48).generate(seed ^ 0x9);
    let mut p = HybridParams::new(4);
    p.cpu_ranks = 0; // deterministic replay mode
    p.gpu_drain = mode;
    p.backend = backend;
    p.streams = 2;
    p.buffer_pairs = 20_000;
    if fault {
        p.fault =
            FaultPlan::one(FaultSpec::transient(FaultKind::FilterPanic, 0, 0));
        p.recovery.backoff_base_secs = 0.0;
    }
    let mut eng = KnnEngine::build(engine, &corpus, p).unwrap();
    let mut rng = Rng::new(seed ^ 0xC0DE);
    let mut live = corpus.len();
    let mut live_ids: Vec<u32> = (0..corpus.len() as u32).collect();
    let mut next_extra = 0usize;
    let mut faults = 0usize;
    let tag = format!("{mode:?}/{backend:?}/fault={fault}");
    for step in 0..6 {
        // mutate: a small insert batch and a small remove batch
        let n_ins = (1 + rng.below(6)).min(extra.len() - next_extra);
        if n_ins > 0 {
            let idx: Vec<usize> =
                (next_extra..next_extra + n_ins).collect();
            next_extra += n_ins;
            let ids = eng.insert(&extra.gather(&idx)).unwrap();
            assert_eq!(ids.len(), n_ins, "{tag}: insert acks every row");
            live += n_ins;
            live_ids.extend(ids);
        }
        let n_rem = rng.below(5).min(live_ids.len().saturating_sub(8));
        if n_rem > 0 {
            let mut victims = Vec::with_capacity(n_rem);
            for _ in 0..n_rem {
                victims.push(live_ids.swap_remove(rng.below(live_ids.len())));
            }
            assert_eq!(
                eng.remove(&victims),
                n_rem,
                "{tag}: every victim was live"
            );
            live -= n_rem;
        }
        assert_eq!(eng.live_len(), live, "{tag}: live-set accounting");

        // flush boundary: patched engine vs rebuilt-from-scratch oracle
        let (got, grep) = eng.flush(&queries).unwrap();
        let mut oracle = eng.rebuilt();
        assert_eq!(oracle.epoch(), eng.epoch(), "{tag}: epoch carried");
        assert_eq!(oracle.live_len(), live, "{tag}: oracle live set");
        let (want, wrep) = oracle.flush(&queries).unwrap();
        for q in 0..queries.len() {
            let (g, w) = (got.get(q), want.get(q));
            assert_eq!(
                g.ids(),
                w.ids(),
                "{tag} step={step} q={q}: id lane diverged from rebuild"
            );
            let gb: Vec<u64> =
                g.dist2s().iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u64> =
                w.dist2s().iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "{tag} step={step} q={q}: dist2 bits");
        }
        // same solved/failed partition, same exactly-once accounting
        assert_eq!(grep.queries, queries.len(), "{tag}: queries served");
        assert_eq!(
            grep.q_gpu + grep.q_cpu,
            queries.len(),
            "{tag}: head/tail claims partition the flush"
        );
        assert_eq!(grep.q_gpu, wrep.q_gpu, "{tag}: head claim count");
        assert_eq!(grep.q_cpu, wrep.q_cpu, "{tag}: tail claim count");
        assert_eq!(grep.q_fail, wrep.q_fail, "{tag}: Q^Fail recirculation");
        assert_eq!(
            grep.solved_on_gpu, wrep.solved_on_gpu,
            "{tag}: GPU-solved partition"
        );
        faults += grep.gpu_faults;
    }
    faults
}

#[test]
fn churned_engine_bit_identical_to_rebuild_across_modes_and_tiers() {
    let engine = Engine::load_default().unwrap();
    for (i, mode) in drain_modes().into_iter().enumerate() {
        for (j, backend) in
            [BackendMode::Grid, BackendMode::Brute].into_iter().enumerate()
        {
            churn_harness(
                &engine,
                mode,
                backend,
                0xD00D ^ ((i as u64) << 8) ^ ((j as u64) << 4),
                false,
            );
        }
    }
}

#[test]
fn churned_engine_bit_identical_to_rebuild_under_fault_injection() {
    // the injected filter panic (claim 0, round 0, every drain) must be
    // recovered claim-scoped on BOTH engines, leaving the equivalence
    // intact - and it must actually fire
    let engine = Engine::load_default().unwrap();
    for (i, mode) in drain_modes().into_iter().enumerate() {
        let faults = churn_harness(
            &engine,
            mode,
            BackendMode::Grid,
            0xFA17 ^ ((i as u64) << 8),
            true,
        );
        assert!(faults >= 1, "{mode:?}: injected fault never observed");
    }
}

// ---------------------------------------------------------------------
// Service-level churn: Client::{insert,remove} through the serve loop
// ---------------------------------------------------------------------

#[test]
fn service_mutations_are_fifo_visible_to_later_queries() {
    // strict FIFO from one client: an insert acked before a query is
    // visible to it (zero-distance twin), a remove acked before a query
    // makes the ids unreachable - no epoch leaks across the boundary
    let engine = Engine::load_default().unwrap();
    let corpus = susy_like(300).generate(0xD1);
    let extra = susy_like(2).generate(0xD2);
    let mut p = HybridParams::new(3);
    p.cpu_ranks = 0;
    let mut session = KnnEngine::build(&engine, &corpus, p).unwrap();
    let ingress = Ingress::new();
    std::thread::scope(|s| {
        let client = ingress.client();
        let extra = &extra;
        let h = s.spawn(move || {
            let ids = client.insert(extra).unwrap();
            assert_eq!(ids.len(), 2);
            assert_eq!(ids[0], 300, "corpus ids are append-only");
            let r = client.query(&extra.gather(&[0])).unwrap();
            assert_eq!(r.results.len(), 1);
            assert_eq!(
                r.results[0].ids[0], ids[0],
                "the just-inserted twin is the nearest neighbor"
            );
            assert_eq!(r.results[0].dist2[0].to_bits(), 0f64.to_bits());
            assert_eq!(client.remove(&ids).unwrap(), 2);
            let r2 = client.query(&extra.gather(&[0])).unwrap();
            for &id in &ids {
                assert!(
                    !r2.results[0].ids.contains(&id),
                    "removed id {id} resurfaced as a neighbor"
                );
            }
            assert!(r2.results[0].dist2[0] > 0.0);
        });
        let rep = session.serve(&ingress).unwrap();
        h.join().expect("client thread panicked");
        assert_eq!(rep.inserts, 2);
        assert_eq!(rep.removes, 2);
        assert_eq!(rep.queries, 2);
        assert_eq!(rep.requests, 4);
    });
    assert_eq!(session.live_len(), 300, "back to the original live set");
    assert_eq!(session.epoch(), 4, "2 inserts + 2 removes = 4 epochs");
}
