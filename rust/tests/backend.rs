//! Backend equivalence: the dimension-adaptive router must be invisible
//! in the output. Whatever tier a claim lands on - the grid-hybrid
//! candidate path, the tiled brute-force corpus scan, or the CPU ranks -
//! every query's K nearest neighbors are the same, and the exactly-once
//! claim accounting closes.
//!
//! Two levels of comparison:
//! * drain level (`gpu_join_drain`, GPU only): within one backend the
//!   three drain modes are BIT-identical (checksummed); across backends,
//!   grid-solved queries match the brute tier bit for bit (both tiers
//!   compute the same f32 device distances for the same (q, c) pair),
//!   and grid-failed queries are exactly the ones brute solves that grid
//!   left empty - the brute tier has no ε gate, so a brute claim can
//!   never land in Q^Fail.
//! * hybrid level (`HybridKnnJoin::run`): forced-Grid, forced-Brute and
//!   Auto runs agree with a CPU-only run (ρ = 1) within float tolerance
//!   (Q^Fail re-solves and the CPU reference compute in f64; the device
//!   computes in f32, so cross-path lanes are tolerance-equal, not
//!   bit-equal).

use hybrid_knn_join::gpu::join::gpu_join_drain;
use hybrid_knn_join::prelude::*;
use hybrid_knn_join::sched::{self, BackendMode};

/// CI's chaos matrix pins the GPU drain's pipeline depth via
/// `HKNN_FAULT_DEPTH` (1 = sync, 2 = two-stage, 3 = three-stage) so the
/// recovery paths run under every drain's interleaving; unset, the
/// backend fault test exercises the default three-stage drain.
fn pinned_drain() -> Option<DrainMode> {
    match std::env::var("HKNN_FAULT_DEPTH").ok().as_deref() {
        Some("1") => Some(DrainMode::Sync),
        Some("2") => Some(DrainMode::TwoStage),
        Some("3") => Some(DrainMode::ThreeStage),
        _ => None,
    }
}

/// GPU-only queue drain over all points of `data` (self-join) with a
/// forced backend and drain mode. Returns the table, failed set, stats.
fn drain_backend(
    engine: &Engine,
    data: &Dataset,
    m: usize,
    eps: f64,
    k: usize,
    backend: BackendMode,
    mode: DrainMode,
    fault: FaultPlan,
) -> (KnnResult, Vec<u32>, hybrid_knn_join::gpu::GpuJoinStats) {
    let grid = GridIndex::build(data, m, eps);
    let queries: Vec<u32> = (0..data.len() as u32).collect();
    let queue = build_queue(data, &grid, &queries, k, 0.0, 0.0, true);
    let mut params = GpuJoinParams::new(k, eps);
    params.streams = 2;
    params.buffer_pairs = 4_000;
    params.drain = mode;
    params.backend = backend;
    params.fault = fault;
    let mut result = KnnResult::new(data.len(), k);
    let slots = result.slots();
    let stats = gpu_join_drain(
        engine, data, data, &grid, &queue, &params, &slots,
        queue.len(),
    )
    .unwrap();
    drop(slots);
    assert_eq!(
        stats.solved + stats.failed.len(),
        queries.len(),
        "every claimed query resolved exactly once"
    );
    (result, stats.failed, stats)
}

/// Tolerance-equality of two result tables: same neighbor counts, dist²
/// lanes within relative float tolerance, ids equal except inside tie
/// bands (distances closer than the tolerance can legally swap order
/// between the f32 device path and the f64 host path).
fn assert_equivalent(a: &KnnResult, b: &KnnResult, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: table sizes");
    for q in 0..a.len() {
        let (x, y) = (a.get(q), b.get(q));
        assert_eq!(x.len(), y.len(), "{ctx}: q={q} neighbor count");
        let (xd, yd) = (x.dist2s(), y.dist2s());
        let (xi, yi) = (x.ids(), y.ids());
        for i in 0..xd.len() {
            let tol = 1e-3 * (1.0 + yd[i]);
            assert!(
                (xd[i] - yd[i]).abs() < tol,
                "{ctx}: q={q} i={i} dist² {} vs {}",
                xd[i],
                yd[i]
            );
            if xi[i] != yi[i] {
                let tied = |d: &[f64], j: usize| {
                    (j > 0 && (d[j] - d[j - 1]).abs() < tol)
                        || (j + 1 < d.len() && (d[j + 1] - d[j]).abs() < tol)
                };
                assert!(
                    tied(xd, i) || tied(yd, i),
                    "{ctx}: q={q} i={i} ids {} vs {} differ outside a tie band",
                    xi[i],
                    yi[i]
                );
            }
        }
    }
}

/// The accounting invariants every hybrid run must close, plus the
/// routing-count bookkeeping the backend layer adds.
fn check_accounting(rep: &HybridReport, n: usize, ctx: &str) {
    assert_eq!(rep.q_gpu + rep.q_cpu, n, "{ctx}: split covers the queries");
    assert_eq!(
        rep.solved_on_gpu + rep.q_fail,
        rep.q_gpu,
        "{ctx}: gpu side resolves exactly once"
    );
    let claimed: usize = rep.claims.iter().map(|c| c.queries).sum();
    assert_eq!(claimed, n + rep.q_fail, "{ctx}: claims + recirculated");
    let gpu_recs = rep
        .claims
        .iter()
        .filter(|c| matches!(c.arch, Arch::Gpu))
        .count();
    assert_eq!(
        rep.brute_claims + rep.grid_claims,
        gpu_recs,
        "{ctx}: every GPU claim routed to exactly one tier"
    );
    assert!(
        rep.claims
            .iter()
            .all(|c| !(c.brute && matches!(c.arch, Arch::Cpu))),
        "{ctx}: CPU claims are never brute-routed"
    );
    if rep.brute_claims == 0 {
        assert_eq!(rep.brute_tiles, 0, "{ctx}: no brute tiles without claims");
        assert_eq!(rep.brute_exec_time, 0.0, "{ctx}: no brute exec lane");
    }
    assert!(
        rep.brute_exec_time <= rep.gpu_exec_time + 1e-9,
        "{ctx}: brute lane is a subset of the GPU lane"
    );
}

fn hybrid_params(k: usize, m: usize, backend: BackendMode) -> HybridParams {
    let mut p = HybridParams::new(k);
    p.m = m;
    p.cpu_ranks = 2;
    p.backend = backend;
    p
}

#[test]
fn forced_backends_match_cpu_reference_uniform() {
    // m x k sweep on uniform data: forced-Grid, forced-Brute and Auto
    // all equal the CPU-only reference (ρ=1 ⇒ exact kd-tree KNN).
    let e = Engine::load_default().unwrap();
    let data = susy_like(450).generate(0xBAC0);
    for k in [4usize, 32] {
        let mut cpu_ref = hybrid_params(k, 6, BackendMode::Grid);
        cpu_ref.rho = 1.0;
        let cpu = HybridKnnJoin::run(&e, &data, &cpu_ref).unwrap();
        assert_eq!(cpu.q_gpu, 0);
        assert_eq!(cpu.brute_claims + cpu.grid_claims, 0);
        for m in [2usize, 4, 8] {
            if k == 32 && m == 4 {
                continue; // trim the cross product; (4, 4) covers m=4
            }
            for backend in
                [BackendMode::Grid, BackendMode::Brute, BackendMode::Auto]
            {
                let ctx = format!("m={m} k={k} backend={backend:?}");
                let p = hybrid_params(k, m, backend);
                let rep = HybridKnnJoin::run(&e, &data, &p).unwrap();
                check_accounting(&rep, data.len(), &ctx);
                assert_equivalent(&rep.result, &cpu.result, &ctx);
                match backend {
                    BackendMode::Brute => {
                        assert_eq!(rep.grid_claims, 0, "{ctx}");
                        assert_eq!(
                            rep.q_fail, 0,
                            "{ctx}: brute has no ε gate, so no Q^Fail"
                        );
                        if rep.q_gpu > 0 {
                            assert!(rep.brute_tiles > 0, "{ctx}");
                        }
                    }
                    BackendMode::Grid => {
                        assert_eq!(rep.brute_claims, 0, "{ctx}");
                        assert_eq!(rep.brute_tiles, 0, "{ctx}");
                    }
                    BackendMode::Auto => {} // either tier is legal
                }
            }
        }
    }
}

#[test]
fn forced_backends_match_cpu_reference_skewed() {
    // chist-like clustered Gaussians: dense head cells (big, many-round
    // claims) plus a sparse tail - the shape where routing decisions
    // actually differ per claim.
    let e = Engine::load_default().unwrap();
    let data = chist_like(400).generate(0xBAC1);
    let mut cpu_ref = hybrid_params(4, 6, BackendMode::Grid);
    cpu_ref.rho = 1.0;
    cpu_ref.beta = 0.3;
    let cpu = HybridKnnJoin::run(&e, &data, &cpu_ref).unwrap();
    for m in [2usize, 8] {
        for backend in [BackendMode::Grid, BackendMode::Brute, BackendMode::Auto]
        {
            let ctx = format!("chist m={m} backend={backend:?}");
            let mut p = hybrid_params(4, m, backend);
            p.beta = 0.3;
            let rep = HybridKnnJoin::run(&e, &data, &p).unwrap();
            check_accounting(&rep, data.len(), &ctx);
            assert_equivalent(&rep.result, &cpu.result, &ctx);
        }
    }
}

#[test]
fn forced_backends_match_on_bipartite_join() {
    // R ⋈ S with |R| ≠ |S|: exercises the R-side rank cache the keyed
    // queue build uses, and brute's corpus tiles covering S (not R).
    let e = Engine::load_default().unwrap();
    let r = susy_like(240).generate(0xBAC2);
    let s = susy_like(480).generate(0xBAC3);
    let mut cpu_ref = hybrid_params(4, 4, BackendMode::Grid);
    cpu_ref.rho = 1.0;
    let cpu = HybridKnnJoin::run_rs(&e, &r, &s, &cpu_ref).unwrap();
    for backend in [BackendMode::Grid, BackendMode::Brute, BackendMode::Auto] {
        let ctx = format!("bipartite backend={backend:?}");
        let p = hybrid_params(4, 4, backend);
        let rep = HybridKnnJoin::run_rs(&e, &r, &s, &p).unwrap();
        check_accounting(&rep, r.len(), &ctx);
        assert_equivalent(&rep.result, &cpu.result, &ctx);
        if backend == BackendMode::Brute {
            assert_eq!(rep.q_fail, 0, "{ctx}");
        }
    }
}

#[test]
fn drain_modes_and_backends_bit_identical() {
    // Drain level, GPU only. Within a backend: all three drain modes are
    // bit-identical (checksummed - the satellite `KnnResult::checksum`).
    // Across backends: grid-solved queries match brute bit for bit (same
    // f32 device distances), and grid's failed set is exactly the slots
    // brute fills that grid left empty.
    let e = Engine::load_default().unwrap();
    let data = susy_like(600).generate(0x51DE);
    let modes = [DrainMode::Sync, DrainMode::TwoStage, DrainMode::ThreeStage];
    let mut by_backend = Vec::new();
    for backend in [BackendMode::Grid, BackendMode::Brute] {
        let (res0, failed0, _) = drain_backend(
            &e, &data, 4, 2.0, 6, backend, modes[0], FaultPlan::none(),
        );
        let sum0 = res0.checksum();
        for &mode in &modes[1..] {
            let (res, failed, _) = drain_backend(
                &e, &data, 4, 2.0, 6, backend, mode, FaultPlan::none(),
            );
            assert_eq!(failed0, failed, "{backend:?} {mode:?}: Q^Fail partition");
            assert_eq!(
                sum0,
                res.checksum(),
                "{backend:?} {mode:?}: drain mode visible in the bits"
            );
        }
        by_backend.push((res0, failed0));
    }
    let (grid_res, grid_failed) = &by_backend[0];
    let (brute_res, brute_failed) = &by_backend[1];
    assert!(brute_failed.is_empty(), "brute has no ε gate, so no failures");
    let failed: std::collections::HashSet<u32> =
        grid_failed.iter().copied().collect();
    for q in 0..data.len() {
        let (g, b) = (grid_res.get(q), brute_res.get(q));
        if failed.contains(&(q as u32)) {
            assert_eq!(g.len(), 0, "q={q}: failed slot must be untouched");
            assert_eq!(b.len(), 6, "q={q}: brute fills every slot");
        } else {
            // both tiers computed these on the same device f32 path
            assert_eq!(g.ids(), b.ids(), "q={q}: id lane");
            assert_eq!(g.dist2s(), b.dist2s(), "q={q}: dist² lane");
        }
    }
}

#[test]
fn routing_boundary_ties_go_to_grid() {
    // The heuristic boundary is strict: a claim whose mean candidate
    // population sits exactly ON the crossover routes to the grid tier.
    for (m, k) in [(2usize, 4usize), (6, 16), (8, 32)] {
        let n = 10_000usize;
        let frac = sched::brute_crossover_frac(m, k);
        let at = frac * n as f64;
        assert!(!sched::route_brute(at, n, m, k), "tie must route to grid");
        assert!(sched::route_brute(at + 1.0, n, m, k), "above must route brute");
        assert!(!sched::route_brute(at - 1.0, n, m, k));
    }
    // crossover falls as m and k grow, and stays in its clamp band
    assert!(
        sched::brute_crossover_frac(2, 4) > sched::brute_crossover_frac(8, 32)
    );
    for m in [1usize, 6, 18] {
        for k in [1usize, 64, 1024] {
            let f = sched::brute_crossover_frac(m, k);
            assert!((0.05..=0.95).contains(&f), "clamp band: {f}");
        }
    }
}

#[test]
fn auto_routes_by_candidate_density() {
    let e = Engine::load_default().unwrap();
    let data = susy_like(500).generate(0xBAC4);
    // Degenerate 1-cell grid: every claim's mean candidate population is
    // |D| > crossover·|D| for any crossover < 1, so Auto must route every
    // claim onto the brute tier...
    let (res, failed, stats) = drain_backend(
        &e,
        &data,
        1,
        1.0e12,
        5,
        BackendMode::Auto,
        DrainMode::ThreeStage,
        FaultPlan::none(),
    );
    assert!(failed.is_empty());
    assert_eq!(stats.grid_claims, 0, "dense claims must route brute");
    assert!(stats.brute_claims > 0);
    assert!(stats.brute_tiles > 0);
    assert_eq!(res.solved_count(5), data.len());
    // ...while a fine grid (m=6, small ε: adjacent populations far below
    // the crossover fraction) keeps Auto entirely on the grid tier.
    let (_, _, stats) = drain_backend(
        &e,
        &data,
        6,
        2.0,
        5,
        BackendMode::Auto,
        DrainMode::ThreeStage,
        FaultPlan::none(),
    );
    assert_eq!(stats.brute_claims, 0, "sparse claims must route grid");
    assert_eq!(stats.brute_tiles, 0);
    assert!(stats.grid_claims > 0);
}

#[test]
fn standalone_tiled_brute_matches_kdtree() {
    // The `brute_join_tiled` wrapper (degenerate grid + forced backend)
    // must agree with the host kd-tree - the entry the benches drive.
    let e = Engine::load_default().unwrap();
    let data = susy_like(500).generate(0xBAC5);
    let params = GpuJoinParams::new(5, 1.0);
    let (res, stats) =
        hybrid_knn_join::gpu::brute::brute_join_tiled(&e, &data, &(0..data.len() as u32).collect::<Vec<_>>(), &params)
            .unwrap();
    assert_eq!(stats.grid_claims, 0);
    assert!(stats.brute_tiles > 0);
    assert_eq!(res.solved_count(5), data.len());
    let tree = KdTree::build(&data);
    for q in (0..data.len()).step_by(29) {
        let got = res.get(q);
        let want = tree.knn(&data, data.point(q), 5, q as u32);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g.dist2 - w.dist2).abs() < 1e-3 * (1.0 + w.dist2),
                "q={q}: {g:?} vs {w:?}"
            );
        }
    }
}

#[test]
fn faults_fire_inside_brute_tiles() {
    // The chaos hooks must reach the brute tier's rounds: a transient
    // fault of each kind on a forced-Brute drain recovers in place and
    // leaves the result bit-identical to the fault-free run.
    let e = Engine::load_default().unwrap();
    let data = susy_like(400).generate(0xBAC6);
    let mode = pinned_drain().unwrap_or(DrainMode::ThreeStage);
    let (clean, clean_failed, _) = drain_backend(
        &e, &data, 3, 2.0, 4, BackendMode::Brute, mode, FaultPlan::none(),
    );
    assert!(clean_failed.is_empty());
    let sum = clean.checksum();
    for kind in [
        FaultKind::ExecError,
        FaultKind::TransferError,
        FaultKind::FilterPanic,
    ] {
        let plan = FaultPlan::one(FaultSpec::transient(kind, 0, 0));
        let (res, failed, stats) = drain_backend(
            &e, &data, 3, 2.0, 4, BackendMode::Brute, mode, plan,
        );
        assert!(failed.is_empty(), "{kind:?}: recovery must re-solve");
        assert_eq!(
            sum,
            res.checksum(),
            "{kind:?}: recovered brute run diverged"
        );
        assert!(stats.gpu_faults >= 1, "{kind:?}: fault not observed");
        assert!(stats.gpu_retries >= 1, "{kind:?}: no in-place retry");
        assert!(stats.brute_claims > 0, "{kind:?}: claims must stay brute");
    }
}
