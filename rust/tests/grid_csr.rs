//! CSR grid-engine equivalence suite: the precomputed cell-adjacency
//! walk (and every O(1) id-keyed lookup) must be *bit-equivalent* to an
//! independent recompute-walk reference - same candidate multiset, same
//! order - across uniform, skewed-Gaussian and bipartite workloads and
//! random `m`/`eps`.
//!
//! The reference (`RefGrid`) deliberately shares no code with
//! `index::grid`: cells are keyed by raw coordinate vectors in a
//! `BTreeMap` (no linearisation at all, so it cannot inherit an id
//! collision), and the 3^m block is enumerated lexicographically - the
//! ascending-cell-id order the grid's walk contract promises.

use std::collections::BTreeMap;

use hybrid_knn_join::core::sqdist_prefix;
use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::{prop, rng::Rng};

/// Independent reference grid: coordinate-vector keyed, recompute walk.
struct RefGrid {
    eps: f64,
    m: usize,
    mins: Vec<f64>,
    widths: Vec<u64>,
    /// coord vector -> point ids, ascending (BTreeMap keys iterate in
    /// lexicographic = ascending-linear-id order)
    cells: BTreeMap<Vec<u64>, Vec<u32>>,
}

impl RefGrid {
    fn build(d: &Dataset, m: usize, eps: f64) -> RefGrid {
        let m = m.clamp(1, d.dims());
        let mut mins = vec![f64::INFINITY; m];
        let mut maxs = vec![f64::NEG_INFINITY; m];
        for i in 0..d.len() {
            let p = d.point(i);
            for j in 0..m {
                mins[j] = mins[j].min(p[j] as f64);
                maxs[j] = maxs[j].max(p[j] as f64);
            }
        }
        if d.is_empty() {
            mins.iter_mut().for_each(|x| *x = 0.0);
            maxs.iter_mut().for_each(|x| *x = 0.0);
        }
        let widths: Vec<u64> = (0..m)
            .map(|j| (((maxs[j] - mins[j]) / eps).floor() as u64 + 1).max(1))
            .collect();
        let mut g = RefGrid { eps, m, mins, widths, cells: BTreeMap::new() };
        for i in 0..d.len() {
            let c = g.coords_of(d.point(i));
            g.cells.entry(c).or_default().push(i as u32);
        }
        g
    }

    /// Clamped cell coordinates (same clamp semantics the engine uses for
    /// arbitrary - e.g. bipartite R - points).
    fn coords_of(&self, p: &[f32]) -> Vec<u64> {
        (0..self.m)
            .map(|j| {
                let c = ((p[j] as f64 - self.mins[j]) / self.eps).floor();
                if c > 0.0 {
                    (c as u64).min(self.widths[j] - 1)
                } else {
                    0
                }
            })
            .collect()
    }

    fn cell_population(&self, p: &[f32]) -> usize {
        self.cells.get(&self.coords_of(p)).map_or(0, |v| v.len())
    }

    /// Recompute walk: enumerate the clipped {-1,0,1}^m block in
    /// lexicographic (ascending cell id) order.
    fn candidates(&self, p: &[f32]) -> Vec<u32> {
        let base = self.coords_of(p);
        let mut out = Vec::new();
        let mut offs = vec![-1i64; self.m];
        let mut key = vec![0u64; self.m];
        'outer: loop {
            let mut ok = true;
            for j in 0..self.m {
                let c = base[j] as i64 + offs[j];
                if c < 0 || (c as u64) >= self.widths[j] {
                    ok = false;
                    break;
                }
                key[j] = c as u64;
            }
            if ok {
                if let Some(ids) = self.cells.get(&key) {
                    out.extend_from_slice(ids);
                }
            }
            for j in (0..self.m).rev() {
                if offs[j] < 1 {
                    offs[j] += 1;
                    continue 'outer;
                }
                offs[j] = -1;
            }
            break;
        }
        out
    }
}

fn random_gauss(rng: &mut Rng, n: usize, dims: usize, scale: f64) -> Dataset {
    let data: Vec<f32> = (0..n * dims)
        .map(|_| rng.normal(0.0, scale) as f32)
        .collect();
    Dataset::new(data, dims)
}

/// Every id-keyed and coordinate-keyed access of a grid-native point
/// must match the reference bit for bit.
fn check_native(d: &Dataset, g: &GridIndex, r: &RefGrid) {
    let mut buf: Vec<u32> = Vec::new();
    for i in 0..d.len() {
        let p = d.point(i);
        let want = r.candidates(p);
        assert_eq!(
            g.candidates_of(p),
            want,
            "coordinate-keyed candidates, point {i}"
        );
        g.candidates_into_id(i as u32, &mut buf);
        assert_eq!(buf, want, "id-keyed candidates, point {i}");
        let mut visited: Vec<u32> = Vec::new();
        g.visit_adjacent_of_id(i as u32, |ids| visited.extend_from_slice(ids));
        assert_eq!(visited, want, "visit_adjacent_of_id order, point {i}");
        assert_eq!(
            g.adjacent_population_of_id(i as u32),
            want.len(),
            "memoized adjacent population, point {i}"
        );
        assert_eq!(
            g.cell_population_of_id(i as u32),
            r.cell_population(p),
            "O(1) cell population, point {i}"
        );
        // the O(1) rank map agrees with the coordinate recompute
        assert_eq!(g.cell_rank_of(i as u32), g.cell_rank_of_point(p).unwrap());
        assert_eq!(g.cell_id_of_id(i as u32), g.cell_id_of(p));
    }
}

#[test]
fn csr_matches_reference_on_uniform_data() {
    prop::cases(10, 0x6C51, |rng| {
        let d = susy_like(200 + rng.below(400)).generate(rng.next_u64());
        let m = 1 + rng.below(6);
        let eps = 1.0 + rng.f64() * 3.0;
        let g = GridIndex::build(&d, m, eps);
        assert_eq!(g.m, m, "benign extents must not degrade m");
        check_native(&d, &g, &RefGrid::build(&d, m, eps));
    });
}

#[test]
fn csr_matches_reference_on_skewed_gaussian() {
    prop::cases(8, 0x6C52, |rng| {
        let d = chist_like(150 + rng.below(350)).generate(rng.next_u64());
        let m = 1 + rng.below(6);
        let eps = 0.4 + rng.f64() * 1.6;
        let g = GridIndex::build(&d, m, eps);
        check_native(&d, &g, &RefGrid::build(&d, m, eps));
    });
}

#[test]
fn csr_matches_reference_on_random_clusters() {
    prop::cases(10, 0x6C53, |rng| {
        let dims = 2 + rng.below(5);
        let d = random_gauss(rng, 150 + rng.below(250), dims, 3.0);
        let m = 1 + rng.below(dims);
        let eps = 0.5 + rng.f64() * 2.0;
        let g = GridIndex::build(&d, m, eps);
        check_native(&d, &g, &RefGrid::build(&d, m, eps));
    });
}

#[test]
fn on_demand_budget_matches_reference_at_adversarial_eps_m() {
    // Carried item (o): at pathological eps/m the worst-case CSR table
    // is O(|B|·3^m) - here 3^8 = 6561 potential row entries per cell
    // over a corpus where almost every point is its own cell. A byte
    // budget that cannot hold the rows must fall back to on-demand
    // adjacency walks with *identical* semantics: same candidate
    // lists, same walk order, same memoized populations as both the
    // unbudgeted build and the independent reference.
    let mut rng = Rng::new(0x0DB1);
    let dims = 8;
    // scale kept small enough that the widths product still fits u64
    // (no m degradation - the reference indexes the same 8 dims)
    let d = random_gauss(&mut rng, 500, dims, 15.0);
    let m = 8;
    let eps = 0.75; // tiny cells: ~500 singleton cells over 8 dims
    let full = GridIndex::build(&d, m, eps);
    assert_eq!(full.m, 8, "extents must not degrade m here");
    assert!(
        !full.adj_is_on_demand(),
        "default budget holds this corpus (worst case ~13 MB)"
    );
    // 1 MB cannot hold 500 cells x 6561 entries x 4 bytes worst case
    let lean = GridIndex::build_with_budget(&d, m, eps, 1 << 20);
    assert!(lean.adj_is_on_demand(), "budget must rule out CSR rows");
    assert_eq!(lean.adj_table_entries(), 0, "no rows materialised");
    assert!(full.adj_table_entries() > 0);

    let r = RefGrid::build(&d, m, eps);
    check_native(&d, &lean, &r);
    let mut buf_full = Vec::new();
    let mut buf_lean = Vec::new();
    for i in 0..d.len() as u32 {
        full.candidates_into_id(i, &mut buf_full);
        lean.candidates_into_id(i, &mut buf_lean);
        assert_eq!(buf_full, buf_lean, "budgeted walk diverged, point {i}");
        assert_eq!(
            full.adjacent_population_of_id(i),
            lean.adjacent_population_of_id(i),
            "memoized population diverged, point {i}"
        );
    }
}

#[test]
fn on_demand_mode_survives_churn_canonically() {
    // mutations in on-demand mode patch the memoized populations by
    // recomputing the touched block - the rebuild-equivalence oracle
    // must hold exactly as it does for materialised rows
    let mut rng = Rng::new(0x0DB2);
    let mut d = random_gauss(&mut rng, 200, 5, 10.0);
    let m = 5;
    let eps = 0.6;
    let mut g = GridIndex::build_with_budget(&d, m, eps, 0);
    assert!(g.adj_is_on_demand());
    let r_ref = RefGrid::build(&d, m, eps);
    check_native(&d, &g, &r_ref);
    let mut live: Vec<u32> = (0..200).collect();
    for step in 0..40 {
        if live.is_empty() || step % 3 != 0 {
            let row: Vec<f32> = (0..5).map(|_| rng.normal(0.0, 10.0) as f32).collect();
            let id = d.push_row(&row);
            g.insert(&d, id);
            live.push(id);
        } else {
            let id = live.swap_remove(rng.below(live.len()));
            assert!(g.remove(id));
        }
        if step % 8 == 0 {
            g.assert_same_layout(&g.rebuilt(&d));
        }
    }
    g.assert_same_layout(&g.rebuilt(&d));
    // walks remain complete after churn: every live in-eps neighbor
    // (in the indexed projection, under the frozen clamped geometry)
    // is still found
    for &q in live.iter().step_by(11) {
        let cands: std::collections::HashSet<u32> =
            g.candidates_of(d.point(q as usize)).into_iter().collect();
        for &i in &live {
            if sqdist_prefix(d.point(q as usize), d.point(i as usize), m) <= eps * eps {
                assert!(
                    cands.contains(&i),
                    "post-churn walk missed live neighbor {i} of {q}"
                );
            }
        }
    }
}

#[test]
fn csr_matches_reference_on_bipartite_queries() {
    // R queries against an S grid: coordinate-keyed walks over points the
    // grid does not index, including points far outside the S extent
    // (empty clamped cells take the fallback recompute walk).
    prop::cases(10, 0x6C54, |rng| {
        let dims = 2 + rng.below(4);
        let s = random_gauss(rng, 150 + rng.below(300), dims, 2.0);
        let m = 1 + rng.below(dims);
        let eps = 0.5 + rng.f64() * 1.5;
        let g = GridIndex::build(&s, m, eps);
        let r_ref = RefGrid::build(&s, m, eps);
        // wilder extent than S on purpose
        let r = random_gauss(rng, 80, dims, 2.0 + rng.f64() * 20.0);
        let mut buf: Vec<u32> = Vec::new();
        for q in 0..r.len() {
            let p = r.point(q);
            let want = r_ref.candidates(p);
            assert_eq!(g.candidates_of(p), want, "R query {q}");
            g.candidates_into(p, &mut buf);
            assert_eq!(buf, want, "R query {q} (scratch form)");
            assert_eq!(g.adjacent_population(p), want.len(), "R query {q}");
            assert_eq!(g.cell_population(p), r_ref.cell_population(p));
            // completeness: the walk is a superset of the true in-eps
            // neighborhood in the indexed projection
            for i in 0..s.len() {
                if sqdist_prefix(p, s.point(i), m) <= eps * eps {
                    assert!(
                        want.contains(&(i as u32)),
                        "R query {q}: S neighbor {i} missed"
                    );
                }
            }
        }
    });
}
