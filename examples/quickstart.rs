//! Quickstart: the 20-line path from a dataset to its KNN self-join.
//!
//!   cargo run --release --example quickstart
//!
//! Requires `make artifacts` (the AOT-compiled HLO tiles) once.

use hybrid_knn_join::prelude::*;

fn main() -> anyhow::Result<()> {
    // the "device": PJRT CPU client + AOT artifacts
    let engine = Engine::load_default()?;

    // a 10k-point, 18-D clustered dataset (SuSy surrogate)
    let data = susy_like(10_000).generate(7);

    // K=5 self-join with default parameters (beta=gamma=rho=0)
    let mut params = HybridParams::new(5);
    params.gamma = 0.6; // dense cells to the GPU
    params.rho = 0.3;   // keep the CPU busy
    let report = HybridKnnJoin::run(&engine, &data, &params)?;

    println!(
        "solved {}/{} queries in {:.3}s (GPU {} / CPU {} / failed->CPU {})",
        report.result.solved_count(5),
        data.len(),
        report.response_time,
        report.q_gpu,
        report.q_cpu,
        report.q_fail,
    );
    let q = 42;
    println!("nearest 5 of point {q}:");
    for n in report.result.get(q) {
        println!("  id {:>6}  dist {:.4}", n.id, n.dist2.sqrt());
    }
    Ok(())
}
