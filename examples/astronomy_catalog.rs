//! Astronomy catalog scenario (the paper's introductory motivation [3]:
//! "within an astronomy catalog, find the closest five objects of all
//! objects within a feature space").
//!
//! Builds a synthetic photometric catalog - 5-D color-index feature
//! vectors with realistic cluster structure (stellar populations) plus a
//! sparse halo - and self-joins it with K=5, then reports per-population
//! nearest-neighbor statistics, comparing the hybrid engine against the
//! CPU-only reference for the same result.

use hybrid_knn_join::data::variance::reorder_by_variance;
use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::rng::Rng;

/// Synthetic photometric catalog: u-g, g-r, r-i, i-z colors + magnitude.
fn synth_catalog(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    // three stellar populations + halo contamination
    let pops = [
        ([0.8f64, 0.4, 0.15, 0.05, 16.0], 0.08, 0.55), // main sequence
        ([1.4, 0.7, 0.35, 0.20, 18.5], 0.15, 0.25),    // red giants
        ([0.2, -0.1, -0.15, -0.1, 20.0], 0.10, 0.12),  // blue stragglers
    ];
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        let u = rng.f64();
        let mut acc = 0.0;
        let mut row = None;
        for (center, sd, w) in pops {
            acc += w;
            if u <= acc {
                row = Some(
                    center
                        .iter()
                        .map(|&c| (c + rng.normal(0.0, sd)) as f32)
                        .collect::<Vec<f32>>(),
                );
                break;
            }
        }
        rows.push(row.unwrap_or_else(|| {
            // halo: broad uniform colors
            (0..5).map(|_| rng.range(-1.0, 3.0) as f32).collect()
        }));
    }
    Dataset::from_rows(&rows)
}

fn main() -> anyhow::Result<()> {
    let engine = Engine::load_default()?;
    let catalog = synth_catalog(12_000, 0xA57);
    println!("catalog: {} objects x {} features", catalog.len(), catalog.dims());

    let mut params = HybridParams::new(5);
    params.m = 5;
    params.gamma = 0.4;
    params.rho = 0.4;
    let report = HybridKnnJoin::run(&engine, &catalog, &params)?;
    println!(
        "hybrid: {:.3}s  (GPU {} queries, CPU {}, failed {})",
        report.response_time, report.q_gpu, report.q_cpu, report.q_fail
    );

    // validate against the CPU-only reference
    let (rdata, _) = reorder_by_variance(&catalog);
    let tree = KdTree::build(&rdata);
    let reference = ref_impl(&rdata, &tree, 5, 4);
    println!("refimpl: {:.3}s", reference.total_time);
    let mut max_err = 0f64;
    for q in (0..catalog.len()).step_by(251) {
        for (a, b) in report.result.get(q).iter().zip(reference.result.get(q)) {
            max_err = max_err.max((a.dist2 - b.dist2).abs());
        }
    }
    println!("max |dist2 - ref| over sampled queries: {max_err:.2e}");

    // nearest-neighbor distance distribution (crowding measure)
    let mut nn: Vec<f64> = (0..catalog.len())
        .filter(|&q| !report.result.get(q).is_empty())
        .map(|q| report.result.get(q).at(0).dist2.sqrt())
        .collect();
    nn.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| nn[((nn.len() - 1) as f64 * p) as usize];
    println!(
        "nearest-neighbor distance: p10={:.4} p50={:.4} p90={:.4}",
        pct(0.1), pct(0.5), pct(0.9)
    );
    println!("dense-core objects (NN < p10): candidates for blend analysis");
    Ok(())
}
