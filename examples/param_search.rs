//! Parameter search (paper Sec. VI-E2): the low-budget grid search over
//! (beta, gamma) on a query fraction f, followed by the analytic
//! rho^Model refinement of Eq. 6 - the exact procedure the paper uses to
//! configure HYBRIDKNN-JOIN for a new dataset.

use hybrid_knn_join::prelude::*;

fn main() -> anyhow::Result<()> {
    let engine = Engine::load_default()?;
    let data = chist_like(8_000).generate(3);
    let k = 10;
    println!(
        "parameter search on CHist* surrogate: |D|={} n={} K={k}",
        data.len(),
        data.dims()
    );

    // stage 1: (beta, gamma) grid at rho=0.5 on a 10% query sample
    let grid = [(0.0, 0.0), (0.0, 0.8), (1.0, 0.0), (1.0, 0.8)];
    let mut best: Option<(f64, f64, f64, f64)> = None; // beta,gamma,time,rho_model
    for (beta, gamma) in grid {
        let mut p = HybridParams::new(k);
        p.beta = beta;
        p.gamma = gamma;
        p.rho = 0.5;
        p.query_fraction = 0.1;
        let rep = HybridKnnJoin::run(&engine, &data, &p)?;
        println!(
            "  beta={beta:.1} gamma={gamma:.1}: {:.3}s (sampled)  T1={:.2e} T2={:.2e} rho_model={:.3}",
            rep.response_time, rep.t1, rep.t2, rep.rho_model
        );
        if best.map(|b| rep.response_time < b.2).unwrap_or(true) {
            best = Some((beta, gamma, rep.response_time, rep.rho_model));
        }
    }
    let (beta, gamma, _, rho_model) = best.unwrap();
    println!("selected beta={beta:.1} gamma={gamma:.1} rho_model={rho_model:.3}");

    // stage 2: full run with the tuned parameters vs the naive default
    let mut tuned = HybridParams::new(k);
    tuned.beta = beta;
    tuned.gamma = gamma;
    tuned.rho = rho_model;
    let t_tuned = HybridKnnJoin::run(&engine, &data, &tuned)?;

    let mut naive = HybridParams::new(k);
    naive.rho = 0.5;
    let t_naive = HybridKnnJoin::run(&engine, &data, &naive)?;

    println!(
        "full run: tuned {:.3}s vs naive(rho=0.5) {:.3}s  speedup {:.2}x",
        t_tuned.response_time,
        t_naive.response_time,
        t_naive.response_time / t_tuned.response_time
    );
    Ok(())
}
