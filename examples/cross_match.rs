//! Catalog cross-match: the bipartite join R ⋈_KNN S (paper Sec. III
//! notes the self-join machinery "is also directly applicable to the case
//! where there are two datasets R and S"). A classic astronomy use: match
//! every object of a new survey (R) against a reference catalog (S),
//! then build the k-distance diagram and run DBSCAN on the reference
//! catalog - the full application stack on one dataset pair.

use hybrid_knn_join::apps::{
    connected_components, dbscan, k_distance_curve, mutual_knn_graph,
    suggest_dbscan_eps, DbscanParams,
};
use hybrid_knn_join::prelude::*;

fn main() -> anyhow::Result<()> {
    let engine = Engine::load_default()?;

    // reference catalog S and a smaller new-survey catalog R drawn from a
    // shifted version of the same sky region (chist-like 32-D features)
    let s = chist_like(9_000).generate(11);
    let r = chist_like(1_500).generate(12);

    println!("cross-match: |R|={} x |S|={} ({}-D)", r.len(), s.len(), s.dims());
    let mut p = HybridParams::new(3);
    p.gamma = 0.3;
    p.rho = 0.3;
    let rep = HybridKnnJoin::run_rs(&engine, &r, &s, &p)?;
    println!(
        "matched {} queries in {:.3}s (GPU {}, CPU {}, failed->CPU {})",
        rep.result.solved_count(3),
        rep.response_time,
        rep.q_gpu,
        rep.q_cpu,
        rep.q_fail
    );
    let mut match_d: Vec<f64> = (0..r.len())
        .filter(|&q| !rep.result.get(q).is_empty())
        .map(|q| rep.result.get(q).at(0).dist2.sqrt())
        .collect();
    match_d.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| match_d[((match_d.len() - 1) as f64 * p) as usize];
    println!(
        "match distance: p10={:.3} p50={:.3} p90={:.3} (threshold for a \
         'confident counterpart' would sit near p10)",
        pct(0.1), pct(0.5), pct(0.9)
    );

    // application stack on the reference catalog: self-join -> k-distance
    // -> DBSCAN eps -> clusters + kNN-graph components
    let mut ps = HybridParams::new(4);
    ps.gamma = 0.3;
    let selfj = HybridKnnJoin::run(&engine, &s, &ps)?;
    let curve = k_distance_curve(&selfj.result, 4);
    let eps = suggest_dbscan_eps(&curve);
    println!("k-distance knee suggests DBSCAN eps = {eps:.3}");
    let cl = dbscan(&s, &DbscanParams { eps, min_pts: 8, m: 6 });
    println!(
        "DBSCAN: {} clusters, {} noise points ({:.1}%)",
        cl.clusters,
        cl.noise,
        100.0 * cl.noise as f64 / s.len() as f64
    );
    let graph = mutual_knn_graph(&selfj.result, 4);
    let (_, comps) = connected_components(&graph);
    println!("mutual 4-NN graph: {} edges, {comps} components", graph.edge_count());
    Ok(())
}
