//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): exercises every layer of the
//! stack on all four surrogate workloads and reports the paper's headline
//! metric - HYBRIDKNN-JOIN speedup over the parallel CPU reference - plus
//! exactness validation of every result against the kd-tree oracle.
//!
//! Layers proven to compose here:
//!   L1 pallas dist/hist kernels -> L2 jax graphs -> AOT HLO artifacts ->
//!   rust PJRT runtime -> grid join engine -> hybrid scheduler (epsilon
//!   selection, beta/gamma/rho split, Q^Fail reassignment, rho^Model).

use hybrid_knn_join::bench::{workloads, Table};
use hybrid_knn_join::data::variance::reorder_by_variance;
use hybrid_knn_join::prelude::*;

fn main() -> anyhow::Result<()> {
    let engine = Engine::load_default()?;
    let mut table = Table::new(
        "End-to-end: hybrid vs REFIMPL (K = paper's per-dataset K)",
        &[
            "dataset", "|D|", "n", "K", "hybrid (s)", "refimpl (s)",
            "speedup", "gpu/cpu/fail", "exact?",
        ],
    );

    for w in workloads() {
        let data = w.dataset();
        let k = w.table_k;

        // probe for rho^Model on a query sample (paper Sec. VI-E2)
        let mut probe = HybridParams::new(k);
        probe.rho = 0.5;
        probe.query_fraction = 0.2;
        let pr = HybridKnnJoin::run(&engine, &data, &probe)?;

        // tuned full run
        let mut params = HybridParams::new(k);
        params.rho = pr.rho_model;
        let rep = HybridKnnJoin::run(&engine, &data, &params)?;

        // CPU-only reference (one extra rank, Sec. VI-C)
        let (rdata, _) = reorder_by_variance(&data);
        let tree = KdTree::build(&rdata);
        let reference = ref_impl(&rdata, &tree, k, 4);

        // exactness: every sampled query must match the oracle
        let mut exact = true;
        for q in (0..data.len()).step_by(199) {
            let (got, want) = (rep.result.get(q), reference.result.get(q));
            if got.len() != want.len() {
                exact = false;
                break;
            }
            for (g, r) in got.iter().zip(want) {
                if (g.dist2 - r.dist2).abs() > 1e-3 * (1.0 + r.dist2) {
                    exact = false;
                }
            }
        }

        table.row(vec![
            w.name.into(),
            data.len().to_string(),
            data.dims().to_string(),
            k.to_string(),
            format!("{:.3}", rep.response_time),
            format!("{:.3}", reference.total_time),
            format!("{:.2}x", reference.total_time / rep.response_time),
            format!("{}/{}/{}", rep.q_gpu, rep.q_cpu, rep.q_fail),
            if exact { "yes".into() } else { "NO".to_string() },
        ]);
    }

    println!("{}", table.render());
    println!("(record this table in EXPERIMENTS.md §E2E)");
    Ok(())
}
