//! Perf probe for the GPU-side filter-dominated case (Songs* beta=1).
use hybrid_knn_join::data::variance::reorder_by_variance;
use hybrid_knn_join::prelude::*;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let e = Engine::load_default()?;
    let data = songs_like(5_000).generate(0xDA7A ^ 90);
    let (data, _) = reorder_by_variance(&data);
    let sel = EpsilonSelector::default().select(&e, &data, 16, 1.0)?;
    let grid = GridIndex::build(&data, 6, sel.eps);
    let sp = split_work(&data, &grid, 16, 0.0, 0.2, true);
    let mut params = GpuJoinParams::new(16, sel.eps);
    params.streams = std::env::var("STREAMS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let t0 = Instant::now();
    let out = gpu_join(&e, &data, &grid, &sp.q_gpu, &params)?;
    println!(
        "songs-beta1: total={:.3}s kernel={:.3}s pairs={} solved={}",
        t0.elapsed().as_secs_f64(), out.kernel_time, out.result_pairs, out.solved
    );
    Ok(())
}
