//! Perf-pass probe: where does GPU-JOIN time go?
use hybrid_knn_join::data::variance::reorder_by_variance;
use hybrid_knn_join::prelude::*;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let e = Engine::load_default()?;
    let data = susy_like(20_000).generate(0xDA7A ^ 18);
    let (data, _) = reorder_by_variance(&data);
    let sel = EpsilonSelector::default().select(&e, &data, 1, 0.0)?;
    let grid = GridIndex::build(&data, 6, sel.eps);
    let sp = split_work(&data, &grid, 1, 0.0, 0.0, true);
    println!("|Q_gpu|={} cells(non-empty)={}", sp.q_gpu.len(), grid.non_empty_cells());
    let work = hybrid_knn_join::gpu::join::workload_vector(&grid, &sp.q_gpu);
    let total_work: u64 = work.iter().sum();
    let max_work = work.iter().max().unwrap();
    println!("total candidate-pairs={} max/query={} avg/query={}",
        total_work, max_work, total_work / work.len().max(1) as u64);
    let n0 = e.executions();
    let t0 = Instant::now();
    let mut params = GpuJoinParams::new(1, sel.eps);
    params.streams = std::env::var("STREAMS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let out = gpu_join(&e, &data, &grid, &sp.q_gpu, &params)?;
    println!(
        "join: total={:.3}s kernel={:.3}s execs={} solved={} failed={} pairs={}",
        t0.elapsed().as_secs_f64(), out.kernel_time, e.executions() - n0,
        out.solved, out.failed.len(), out.result_pairs
    );
    Ok(())
}
