use hybrid_knn_join::prelude::*;
use std::time::Instant;
fn main() {
    let e = Engine::load_default().unwrap();
    let mut r = hybrid_knn_join::util::rng::Rng::new(1);
    for (name, qt, ct, d) in [
        ("dist_q128_c512_d24", 128usize, 512usize, 24usize),
        ("disttopk_q128_c512_d24_k64", 128, 512, 24),
        ("dist_q32_c256_d24", 32, 256, 24),
        ("dist_q128_c512_d96", 128, 512, 96),
        ("disttopk_q128_c512_d96_k64", 128, 512, 96),
        ("dist_q128_c512_d520", 128, 512, 520),
        ("hist_s64_c512_d24_b64", 0, 0, 0),
    ] {
        if qt == 0 {
            let q: Vec<f32> = (0..64*24).map(|_| r.normal(0.,1.) as f32).collect();
            let c: Vec<f32> = (0..512*24).map(|_| r.normal(0.,1.) as f32).collect();
            let edges: Vec<f32> = (1..=64).map(|x| x as f32).collect();
            let args: [(&[f32], &[i64]); 3] = [(&q, &[64,24]), (&c, &[512,24]), (&edges, &[64])];
            e.exec(name, &args).unwrap();
            let t0 = Instant::now();
            for _ in 0..20 { e.exec(name, &args).unwrap(); }
            println!("{name}: {:.3} ms/exec", t0.elapsed().as_secs_f64()/20.0*1e3);
            continue;
        }
        let q: Vec<f32> = (0..qt*d).map(|_| r.normal(0.,1.) as f32).collect();
        let c: Vec<f32> = (0..ct*d).map(|_| r.normal(0.,1.) as f32).collect();
        let args: [(&[f32], &[i64]); 2] = [(&q, &[qt as i64, d as i64]), (&c, &[ct as i64, d as i64])];
        e.exec(name, &args).unwrap();
        let t0 = Instant::now();
        for _ in 0..20 { e.exec(name, &args).unwrap(); }
        println!("{name}: {:.3} ms/exec", t0.elapsed().as_secs_f64()/20.0*1e3);
    }
}
